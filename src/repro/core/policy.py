"""Policy interface and primitives (paper §4.2, Table 2).

Policies are ordinary Python programs run by the global controller's
single-threaded, push-based loop.  They inspect a ``ClusterView`` (metrics
aggregated from node stores) and emit actions through the canonical
primitives:

    route(session_id, agent_type, instance)            session pinning
    route_weighted(agent_type, instances, weights)     weighted spraying
    route_tier(agent_type, {tier: [instances]})        model-tier routing
    set_priority(session_id, value[, agent_type])
    migrate(session_id, src_instance, dst_instance)
    migrate_future(fid, dst_instance)
    kill(instance)
    provision(agent_type, node)
    install_schedule(agent_type, LocalSchedule)        local queue ordering

Actions are *written to node stores*; component controllers consume them
asynchronously, keeping the global controller off the critical path.

The library at the bottom contains the paper's three default serving policies
(§6.1) plus the two §6.2 case studies (SRTF ≈12 lines, LPT ≈12 lines).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from .controller_local import LocalSchedule


# ------------------------------------------------------------- cluster view
@dataclass
class InstanceView:
    instance_id: str
    agent_type: str
    node: str
    qsize: int
    busy: bool
    busy_until: float
    ema_service: float
    completed: int
    failed: int
    alive: bool
    waiting_sessions: List[str]
    # futures currently executing (async engine-backed instances carry many)
    inflight: int = 0
    # failure-handling telemetry (mirrors InstanceMetrics)
    retries: int = 0
    cancelled: int = 0
    # deadline enforcement: futures resolved DeadlineExceeded at launch on
    # this instance, and engine requests expired at admission/mid-decode
    expired: int = 0
    engine_expired: int = 0
    # data-plane backpressure (engine-backed instances only): wait-queue
    # depth, depth as a fraction of the admission bound (0.0 = unbounded or
    # empty, >= 1.0 = hard-rejecting), and rejections so far.  Policies use
    # the saturation watermark to shed/reroute *before* collapse.
    engine_queue: int = 0
    engine_saturation: float = 0.0
    engine_rejects: int = 0
    # cross-session prefix-cache effectiveness on this replica: admissions
    # that found a shared prefix resident, and the prefill tokens those hits
    # skipped.  KV-affinity-style policies read these to judge how much
    # prefix residency a replica actually converts into saved prefill.
    engine_prefix_hits: int = 0
    engine_prefix_tokens: int = 0
    # model-tier label of the replica's engine ("" = untiered) plus the
    # speculative-decode gauges: verifier acceptance rate and the
    # acceptance-weighted decode tokens/step (> 1 = speculation paying).
    # TierRoutePolicy builds its tier table from these.
    engine_tier: str = ""
    engine_spec_acceptance: float = 0.0
    engine_decode_tokens_per_step: float = 0.0

    def eta(self, now: float) -> float:
        rem = max(0.0, self.busy_until - now) if self.busy else 0.0
        ema = max(self.ema_service, 1e-3)
        if self.busy and rem == 0.0:
            # async backends never publish busy_until; charge in-flight
            # work at the EMA rate so least-ETA policies see engine load
            rem = self.inflight * ema
        return rem + self.qsize * ema


@dataclass
class ClusterView:
    now: float
    instances: Dict[str, InstanceView] = field(default_factory=dict)
    # agent_type -> [instance_id]
    by_type: Dict[str, List[str]] = field(default_factory=dict)
    # session_id -> priority
    session_priority: Dict[str, float] = field(default_factory=dict)
    # future metadata mirrors collected from node stores (Fig. 10 measures this)
    futures: Dict[str, dict] = field(default_factory=dict)
    # node -> free resources
    node_resources: Dict[str, Dict[str, float]] = field(default_factory=dict)
    # session_id -> (instance holding its K,V cache, cached tokens) — the
    # §4.3.2 residency snapshot, so policies can route for cache affinity
    kv_residency: Dict[str, tuple] = field(default_factory=dict)
    # failures escalated by component controllers awaiting a rerouting
    # decision: dicts with fid/agent_type/session/executor/attempt/
    # escalations/reason/error (consumed by RetryPolicy)
    escalated: List[Dict[str, Any]] = field(default_factory=list)
    # instances the runtime will no longer route to (dead replicas)
    blacklisted: set = field(default_factory=set)
    # in-flight leaf futures eligible for a hedged duplicate: dicts with
    # fid/instance/agent_type/session/elapsed (consumed by HedgePolicy)
    hedge_candidates: List[Dict[str, Any]] = field(default_factory=list)
    # --- delta-maintenance internals (incremental view collection) ---
    # raw (unpruned) waiting_sessions per instance as last read from its
    # mirror, plus the reverse index session -> instances naming it; kept so
    # a session whose liveness flips can re-filter exactly the affected
    # instances instead of rescanning every mirror
    _raw_waiting: Dict[str, List[str]] = field(default_factory=dict,
                                               repr=False, compare=False)
    _waiting_index: Dict[str, set] = field(default_factory=dict,
                                           repr=False, compare=False)
    # which node's store currently homes each future mirror — a stale-copy
    # delete from a previous home must not evict the fresh entry
    _future_home: Dict[str, str] = field(default_factory=dict,
                                         repr=False, compare=False)

    def instances_of(self, agent_type: str) -> List[InstanceView]:
        return [self.instances[i] for i in self.by_type.get(agent_type, [])
                if self.instances[i].alive]

    def idle_instances(self, agent_type: str) -> List[InstanceView]:
        return [iv for iv in self.instances_of(agent_type)
                if not iv.busy and iv.qsize == 0]

    # ------------------------------------------------------------- delta API
    # The global controller maintains ONE long-lived ClusterView and patches
    # it with node-store deltas each round (per-round cost scales with churn,
    # not population).  These are the only mutators it uses; a periodic full
    # rebuild is the drift-correction escape hatch.

    def upsert_instance(self, iid: str, m: Dict[str, Any], default_node: str,
                        is_live) -> InstanceView:
        """Patch (or create) the view of instance ``iid`` from its metrics
        mirror ``m``.  ``is_live(session_id)`` prunes the waiting list."""
        raw = list(m.get("waiting_sessions", []))
        for s in self._raw_waiting.get(iid, ()):
            ids = self._waiting_index.get(s)
            if ids is not None:
                ids.discard(iid)
                if not ids:
                    self._waiting_index.pop(s, None)
        self._raw_waiting[iid] = raw
        for s in raw:
            self._waiting_index.setdefault(s, set()).add(iid)
        iv = InstanceView(
            instance_id=iid,
            agent_type=m.get("agent_type", ""),
            node=m.get("node", default_node),
            qsize=int(m.get("qsize", 0)),
            busy=bool(m.get("busy", False)),
            busy_until=float(m.get("busy_until", 0.0)),
            ema_service=float(m.get("ema_service", 0.0)),
            completed=int(m.get("completed", 0)),
            failed=int(m.get("failed", 0)),
            alive=bool(m.get("alive", True)),
            waiting_sessions=[s for s in raw if is_live(s)],
            inflight=int(m.get("inflight", 0)),
            retries=int(m.get("retries", 0)),
            cancelled=int(m.get("cancelled", 0)),
            expired=int(m.get("expired", 0)),
            engine_expired=int(m.get("engine_expired", 0)),
            engine_queue=int(m.get("engine_queue", 0)),
            engine_saturation=float(m.get("engine_saturation", 0.0)),
            engine_rejects=int(m.get("engine_rejects", 0)),
            engine_prefix_hits=int(m.get("engine_shared_prefix_hits", 0)),
            engine_prefix_tokens=int(m.get("engine_shared_prefix_tokens", 0)),
            engine_tier=str(m.get("engine_tier", "")),
            engine_spec_acceptance=float(
                m.get("engine_spec_acceptance", 0.0)),
            engine_decode_tokens_per_step=float(
                m.get("engine_decode_tokens_per_step", 0.0)),
        )
        old = self.instances.get(iid)
        self.instances[iid] = iv
        if old is None:
            self.by_type.setdefault(iv.agent_type, []).append(iid)
        elif old.agent_type != iv.agent_type:   # defensive: never in practice
            peers = self.by_type.get(old.agent_type, [])
            if iid in peers:
                peers.remove(iid)
            self.by_type.setdefault(iv.agent_type, []).append(iid)
        return iv

    def evict_instance(self, iid: str) -> None:
        iv = self.instances.pop(iid, None)
        for s in self._raw_waiting.pop(iid, ()):
            ids = self._waiting_index.get(s)
            if ids is not None:
                ids.discard(iid)
                if not ids:
                    self._waiting_index.pop(s, None)
        if iv is not None:
            peers = self.by_type.get(iv.agent_type, [])
            if iid in peers:
                peers.remove(iid)
            if not peers:
                self.by_type.pop(iv.agent_type, None)

    def upsert_future_mirror(self, fid: str, h: Dict[str, Any],
                             node: str) -> None:
        self.futures[fid] = h
        self._future_home[fid] = node

    def evict_future_mirror(self, fid: str, node: str) -> None:
        """Drop the mirror iff ``node`` is its current home: the delete of a
        stale copy on a previous home (mirror re-homed by migration or an
        escalated reroute) must not shadow the fresh upsert."""
        if self._future_home.get(fid) == node:
            self.futures.pop(fid, None)
            self._future_home.pop(fid, None)

    def refresh_waiting(self, sessions, is_live) -> None:
        """Re-filter the waiting lists of every instance naming one of
        ``sessions`` (their liveness flipped since the last round)."""
        stale = set()
        for sid in sessions:
            stale |= self._waiting_index.get(sid, set())
        for iid in stale:
            iv = self.instances.get(iid)
            if iv is not None:
                iv.waiting_sessions = [
                    s for s in self._raw_waiting.get(iid, []) if is_live(s)]


# ------------------------------------------------------------------ actions
@dataclass
class Action:
    kind: str
    payload: Dict[str, Any]


class ActionSink:
    """Collects primitive calls during one policy step."""

    def __init__(self) -> None:
        self.actions: List[Action] = []

    def route(self, session_id: str, agent_type: str, instance: str) -> None:
        self.actions.append(Action("route", dict(session_id=session_id,
                                                 agent_type=agent_type,
                                                 instance=instance)))

    def route_weighted(self, agent_type: str, instances: List[str],
                       weights: List[float]) -> None:
        self.actions.append(Action("route_weighted", dict(
            agent_type=agent_type, instances=instances, weights=weights)))

    def route_tier(self, agent_type: str,
                   tiers: Dict[str, List[str]]) -> None:
        """Install a model-tier routing table: futures carrying a
        ``model_tier`` work hint are routed within ``tiers[hint]`` (with
        shed-watermark fallback to the other tiers — see Router.route)."""
        self.actions.append(Action("route_tier", dict(
            agent_type=agent_type, tiers=tiers)))

    def set_priority(self, session_id: str, priority_value: float,
                     agent: Optional[str] = None) -> None:
        self.actions.append(Action("set_priority", dict(
            session_id=session_id, value=priority_value, agent=agent)))

    def migrate(self, session_id: str, src: str, dst: str) -> None:
        self.actions.append(Action("migrate", dict(
            session_id=session_id, src=src, dst=dst)))

    def migrate_future(self, fid: str, dst: str) -> None:
        self.actions.append(Action("migrate_future", dict(fid=fid, dst=dst)))

    def kill(self, instance: str, drain_to: Optional[str] = None) -> None:
        self.actions.append(Action("kill", dict(instance=instance,
                                                drain_to=drain_to)))

    def provision(self, agent_type: str, node: str) -> None:
        self.actions.append(Action("provision", dict(agent_type=agent_type,
                                                     node=node)))

    def install_schedule(self, agent_type: str, policy: LocalSchedule) -> None:
        self.actions.append(Action("install_schedule", dict(
            agent_type=agent_type, policy=policy)))

    def retry_future(self, fid: str, instance: str) -> None:
        """Re-dispatch an escalated future on ``instance`` (rung 2 of the
        retry ladder: reroute to a surviving replica)."""
        self.actions.append(Action("retry_future", dict(fid=fid,
                                                        instance=instance)))

    def fail_future(self, fid: str, reason: str = "") -> None:
        """Give up on an escalated future: fail it with its original error."""
        self.actions.append(Action("fail_future", dict(fid=fid,
                                                       reason=reason)))

    def blacklist(self, instance: str) -> None:
        """Remove ``instance`` from every routing decision from now on."""
        self.actions.append(Action("blacklist", dict(instance=instance)))

    def hedge_future(self, fid: str, instance: str) -> None:
        """Dispatch a duplicate of a straggling in-flight future on
        ``instance`` (first completion wins; the loser is cancelled)."""
        self.actions.append(Action("hedge_future", dict(fid=fid,
                                                        instance=instance)))


class Policy:
    """Base class.  ``step`` runs once per global-controller period."""

    name = "base"

    def step(self, view: ClusterView, act: ActionSink) -> None:
        raise NotImplementedError


class PolicyChain(Policy):
    def __init__(self, *policies: Policy) -> None:
        self.policies = list(policies)
        self.name = "+".join(p.name for p in policies)

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for p in self.policies:
            p.step(view, act)


# ---------------------------------------------------------------- library
class LoadBalancePolicy(Policy):
    """Default policy 1 (§6.1): balance load across instances via routing.

    Installs weighted routing inversely proportional to instance ETA.
    """

    name = "load_balance"

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for agent_type, ids in view.by_type.items():
            ivs = view.instances_of(agent_type)
            if len(ivs) < 2:
                continue
            etas = [iv.eta(view.now) for iv in ivs]
            # a replica whose admission queue is saturated is about to
            # hard-reject: spray almost nothing its way until it drains
            weights = [(1.0 / (0.05 + e))
                       * (0.01 if iv.engine_saturation >= 1.0 else 1.0)
                       for iv, e in zip(ivs, etas)]
            s = sum(weights)
            act.route_weighted(agent_type, [iv.instance_id for iv in ivs],
                               [w / s for w in weights])


class TierRoutePolicy(Policy):
    """Just-in-time model-tier routing: publish a tier table built from each
    replica's self-reported ``engine_tier`` so the router can steer cheap
    steps (futures hinted ``model_tier="small"``) to small-tier replicas and
    hard steps to large ones.  The SLO-aware part lives in the router: a
    tier whose every replica sits at or above the shed watermark falls
    through to the remaining tiers, composing with the fresh-traffic shed
    rather than fighting it — a hint is a preference, never a hard pin.
    """

    name = "tier_route"

    def __init__(self) -> None:
        self._last: Dict[str, Dict[str, List[str]]] = {}

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for agent_type in view.by_type:
            tiers: Dict[str, List[str]] = {}
            for iv in view.instances_of(agent_type):
                if iv.engine_tier:
                    tiers.setdefault(iv.engine_tier, []).append(
                        iv.instance_id)
            for ids in tiers.values():
                ids.sort()
            if not tiers:           # untiered pool: nothing to install
                continue
            if self._last.get(agent_type) != tiers:
                act.route_tier(agent_type, tiers)
                self._last[agent_type] = tiers


class HoLMitigationPolicy(Policy):
    """Default policy 2 (§6.1): migrate sessions stuck behind long work.

    If a session waits in a busy instance's queue while a sibling instance is
    idle, migrate the session there.  (Generalizes the Fig. 6 example.)
    """

    name = "hol_mitigation"

    def __init__(self, wait_threshold: float = 0.5) -> None:
        self.wait_threshold = wait_threshold

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for agent_type in view.by_type:
            ivs = view.instances_of(agent_type)
            idle = [iv for iv in ivs if not iv.busy and iv.qsize == 0]
            if not idle:
                continue
            busy = sorted((iv for iv in ivs if iv.qsize > 0),
                          key=lambda iv: -iv.eta(view.now))
            for iv in busy:
                if iv.eta(view.now) < self.wait_threshold or not idle:
                    break
                # prefer the highest-priority waiting session
                sessions = sorted(
                    iv.waiting_sessions,
                    key=lambda s: -view.session_priority.get(s, 0.0))
                if not sessions:
                    continue
                dst = idle.pop(0)
                act.migrate(sessions[0], iv.instance_id, dst.instance_id)


class ResourceReassignmentPolicy(Policy):
    """Default policy 3 (§6.1): move capacity from low-load to high-load types.

    If an agent type's average queue exceeds ``hot`` while another type sits
    idle (< ``cold``) and shares a resource profile, kill one cold instance
    and provision a hot one on the freed node.
    """

    name = "resource_reassignment"

    def __init__(self, hot: float = 4.0, cold: float = 0.25,
                 cooldown: float = 5.0) -> None:
        self.hot = hot
        self.cold = cold
        self.cooldown = cooldown
        self._last_change = -1e9

    def step(self, view: ClusterView, act: ActionSink) -> None:
        if view.now - self._last_change < self.cooldown:
            return
        load: Dict[str, float] = {}
        for agent_type in view.by_type:
            ivs = view.instances_of(agent_type)
            if ivs:
                load[agent_type] = sum(iv.qsize for iv in ivs) / len(ivs)
        if not load:
            return
        hot_type = max(load, key=load.get)
        cold_candidates = [t for t, l in load.items()
                           if t != hot_type and l <= self.cold
                           and len(view.instances_of(t)) > 1]
        if load[hot_type] < self.hot or not cold_candidates:
            return
        cold_type = min(cold_candidates, key=lambda t: load[t])
        victims = sorted(view.instances_of(cold_type),
                         key=lambda iv: iv.eta(view.now))
        victim = victims[0]
        survivors = [iv for iv in view.instances_of(cold_type)
                     if iv.instance_id != victim.instance_id]
        act.kill(victim.instance_id,
                 drain_to=survivors[0].instance_id if survivors else None)
        act.provision(hot_type, victim.node)
        self._last_change = view.now


class SRTFSchedule(LocalSchedule):
    """Shortest-remaining-time-first local queue order (§6.2 Minimize JCT).

    In call-graph workloads, later-stage calls have less remaining work, so
    deeper futures run first; ties broken by expected service time.
    """

    name = "srtf"

    def order_key(self, fut, now: float):
        depth = fut.meta.work_hint.get("graph_depth", 0)
        est = fut.meta.work_hint.get("est_service", 1.0)
        return (-depth, est, fut.meta.created_at)


class SRTFPolicy(Policy):
    """The paper's 12-line JCT policy: install SRTF ordering everywhere."""

    name = "srtf"

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for agent_type in view.by_type:
            act.install_schedule(agent_type, SRTFSchedule())


class LPTSchedule(LocalSchedule):
    """Longest-processing-time-first (§6.2 Control Makespan): re-entrant
    (retried) jobs first, then longest estimated service."""

    name = "lpt"

    def order_key(self, fut, now: float):
        # re-entrance comes from either the driver's own retry loop (the
        # "retry" hint, Fig. 4 style) or the runtime's retry ladder (the
        # attempt counter on re-dispatched futures)
        retries = max(fut.meta.work_hint.get("retry", 0),
                      getattr(fut.meta, "attempt", 0))
        est = fut.meta.work_hint.get("est_service", 1.0)
        return (-retries, -est, fut.meta.created_at)


class LPTPolicy(Policy):
    name = "lpt"

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for agent_type in view.by_type:
            act.install_schedule(agent_type, LPTSchedule())


class KVAffinityPolicy(Policy):
    """Pin every session to the instance holding its K,V cache (§4.3.2
    expressed as a ~10-line §4.2 policy).

    A session whose prefix cache is warm on replica X pays only the new
    suffix on X but a full-context rebuild anywhere else, so the ``route``
    pin is installed for the cache's home replica.  With an
    ``imbalance_eta`` threshold the policy trades affinity for load: when
    the home replica's ETA exceeds the best sibling's by more than the
    threshold, it *migrates* the session there instead — the cache follows
    (transcript replay on engine pools), re-creating affinity at the
    destination instead of fighting it.
    """

    name = "kv_affinity"

    def __init__(self, agent_types: Optional[List[str]] = None,
                 imbalance_eta: Optional[float] = None,
                 max_migrations_per_step: int = 1) -> None:
        self.agent_types = agent_types
        self.imbalance_eta = imbalance_eta
        # migrations are issued against a static view, so each one invisibly
        # shifts the very ETAs the next decision would read; moving one
        # session per round (the next round sees the result) avoids herding
        # every resident session onto the same "best" sibling at once
        self.max_migrations_per_step = max_migrations_per_step

    def step(self, view: ClusterView, act: ActionSink) -> None:
        migrated = 0
        for sid, (iid, _tokens) in view.kv_residency.items():
            home = view.instances.get(iid)
            if home is None or not home.alive:
                continue
            if self.agent_types and home.agent_type not in self.agent_types:
                continue
            if (self.imbalance_eta is not None
                    and migrated < self.max_migrations_per_step
                    # only sessions with pending work are worth a physical
                    # move: waiting_sessions is pruned to live futures at
                    # aggregation, so finished/idle sessions never pay a
                    # transcript replay on the strength of a stale record
                    and sid in home.waiting_sessions):
                siblings = [iv for iv in view.instances_of(home.agent_type)
                            if iv.instance_id != iid]
                if siblings:
                    # prefix-residency tiebreak: among equally loaded
                    # siblings, prefer the replica that has demonstrably
                    # converted resident prefixes into skipped prefill —
                    # its index likely already holds this session's shared
                    # preamble, making the post-migration rebuild cheaper
                    best = min(siblings,
                               key=lambda iv: (iv.eta(view.now),
                                               -iv.engine_prefix_tokens,
                                               iv.instance_id))
                    if (home.eta(view.now) - best.eta(view.now)
                            > self.imbalance_eta):
                        act.migrate(sid, iid, best.instance_id)
                        migrated += 1
                        continue
            act.route(sid, home.agent_type, iid)


class RetryPolicy(Policy):
    """Rung 2 of the retry ladder (§5 fault handling as a §4.2 policy).

    Component controllers retry failures *in place* up to the agent's
    ``max_retries`` budget; what they cannot fix locally — the budget ran
    out, or the instance itself died — they escalate.  Escalations appear in
    ``ClusterView.escalated``; for each one this policy

      1. blacklists the failed executor if it is dead (``ClusterView``
         marks it, and the runtime stops routing there for good),
      2. reroutes the future to the least-loaded *surviving* replica of the
         same agent type (never the one that just failed it), or
      3. fails the future with its original error when no survivor remains
         or the future has been rerouted ``max_reroutes`` times already.

    Installed by default on the global controller (it also runs between
    periodic rounds when a controller nudges an escalation), and swappable
    like any other policy — e.g. a custom subclass could provision a fresh
    replica instead of failing on rung 3.
    """

    name = "retry"

    def __init__(self, max_reroutes: int = 2) -> None:
        self.max_reroutes = max_reroutes

    def step(self, view: ClusterView, act: ActionSink) -> None:
        for rec in view.escalated:
            src = rec.get("executor", "")
            src_view = view.instances.get(src)
            if src_view is not None and not src_view.alive \
                    and src not in view.blacklisted:
                act.blacklist(src)
            if rec.get("escalations", 0) > self.max_reroutes:
                act.fail_future(rec["fid"], reason="reroute budget exhausted")
                continue
            cands = [iv for iv in view.instances_of(rec["agent_type"])
                     if iv.instance_id != src
                     and iv.instance_id not in view.blacklisted]
            if not cands:
                act.fail_future(rec["fid"], reason="no surviving replica")
                continue
            # prefer the session's KV home: replica failure recovery just
            # replayed the transcript there (§4.3.2) — retrying anywhere
            # else pays a cold full-context prefill for nothing
            dst = None
            home = view.kv_residency.get(rec.get("session", ""))
            if home is not None:
                dst = next((iv for iv in cands
                            if iv.instance_id == home[0]), None)
            if dst is None:
                dst = min(cands, key=lambda iv: iv.eta(view.now))
            act.retry_future(rec["fid"], dst.instance_id)


class HedgePolicy(Policy):
    """Hedged dispatch against stragglers (latency faults as a §4.2 policy).

    A replica that is merely *slow* — not dead — stalls every dependent
    future without tripping the retry ladder.  Each round this policy scans
    ``ClusterView.hedge_candidates`` (in-flight leaf futures) and, when one
    has been running ``factor``× longer than the pool's typical service time
    (the *median* of the type's per-replica EMAs, so a straggler's own
    inflated EMA cannot mask it), emits ``hedge_future`` to launch a
    duplicate on the least-loaded below-watermark sibling.  Run-id fencing
    and the terminal-state completion guard make first-completion-wins safe;
    the runtime cancels the loser.

    Two brakes bound the extra work: a global budget (total hedges stay
    under ``budget_frac`` of pool-wide completions, so steady state pays at
    most ~``budget_frac`` extra dispatches) and the shed watermark (no
    sibling below it → no hedge: duplicating work into a saturated pool
    trades one tail for a worse one — composes with PR-5 admission shedding
    rather than fighting it).
    """

    name = "hedge"

    def __init__(self, factor: float = 3.0, min_delay: float = 0.05,
                 budget_frac: float = 0.1, shed_watermark: float = 0.75,
                 agent_types: Optional[List[str]] = None,
                 max_per_round: int = 8) -> None:
        self.factor = factor
        self.min_delay = min_delay
        self.budget_frac = budget_frac
        self.shed_watermark = shed_watermark
        self.agent_types = agent_types
        self.max_per_round = max_per_round
        self.issued = 0

    def _typical_service(self, view: ClusterView, agent_type: str) -> float:
        emas = sorted(iv.ema_service
                      for iv in view.instances_of(agent_type)
                      if iv.ema_service > 0)
        if not emas:
            return 0.0
        return emas[len(emas) // 2]

    def step(self, view: ClusterView, act: ActionSink) -> None:
        cands = view.hedge_candidates
        if not cands:
            return
        completed = sum(iv.completed for iv in view.instances.values())
        # budget brake: never more than budget_frac of all completions (a
        # small floor lets hedging start before completions accumulate)
        budget = max(2.0, self.budget_frac * completed)
        this_round = 0
        for c in sorted(cands, key=lambda c: -c["elapsed"]):
            if self.issued >= budget or this_round >= self.max_per_round:
                return
            at = c["agent_type"]
            if self.agent_types and at not in self.agent_types:
                continue
            typical = self._typical_service(view, at)
            delay = max(self.min_delay, self.factor * typical)
            if c["elapsed"] < delay:
                continue
            siblings = [iv for iv in view.instances_of(at)
                        if iv.instance_id != c["instance"]
                        and iv.instance_id not in view.blacklisted
                        and iv.engine_saturation < self.shed_watermark]
            if not siblings:
                continue        # pool saturated: shed the hedge entirely
            dst = min(siblings, key=lambda iv: (iv.eta(view.now),
                                                iv.instance_id))
            act.hedge_future(c["fid"], dst.instance_id)
            self.issued += 1
            this_round += 1


class HighPrioritySessionPolicy(Policy):
    """Fig. 6 verbatim: boost one session and migrate it away from busy
    instances whenever a sibling instance has an empty queue."""

    name = "high_priority_session"

    def __init__(self, session_id: str, agents: Optional[List[str]] = None,
                 priority_value: float = 10.0) -> None:
        self.session_id = session_id
        self.agents = agents
        self.priority_value = priority_value
        self._boosted = False

    def step(self, view: ClusterView, act: ActionSink) -> None:
        if not self._boosted:
            act.set_priority(self.session_id, self.priority_value)
            self._boosted = True
        for agent_type in (self.agents or list(view.by_type)):
            for iv in view.instances_of(agent_type):
                if self.session_id in iv.waiting_sessions and iv.busy:
                    for other in view.instances_of(agent_type):
                        if other.instance_id != iv.instance_id and \
                                other.qsize == 0 and not other.busy:
                            act.migrate(self.session_id, iv.instance_id,
                                        other.instance_id)
                            return


def default_policies() -> PolicyChain:
    """The three §6.1 defaults, < 100 lines cumulatively."""
    return PolicyChain(LoadBalancePolicy(), HoLMitigationPolicy(),
                       ResourceReassignmentPolicy())
