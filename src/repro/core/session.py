"""Session registry (paper §3.3, §4.3.2).

A *request* is a single inference request from the user; a *session* is a
collection of requests that share context (e.g. a chat).  NALAR assigns every
new session a unique id and propagates it with each future, which is what lets
controllers tag, track, and relocate state without developer involvement.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

# Thread-local execution context: which (session, request) the current code
# runs under.  Stubs read this to tag futures automatically.
_ctx = threading.local()


@dataclass
class SessionInfo:
    session_id: str
    priority: float = 0.0
    created_at: float = 0.0
    # per-agent-type priority overrides (Table 2 set_priority variant 2)
    agent_priority: Dict[str, float] = field(default_factory=dict)
    # requests issued under this session
    request_ids: List[str] = field(default_factory=list)
    active: bool = True

    def priority_for(self, agent_type: str) -> float:
        return self.agent_priority.get(agent_type, self.priority)


class SessionRegistry:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._sessions: Dict[str, SessionInfo] = {}
        # per-runtime counters: session-id strings seed workload RNG
        # streams, so they must be reproducible run-to-run
        self._session_ids = itertools.count()
        self._request_ids = itertools.count()

    def new_session(self, now: float = 0.0, priority: float = 0.0) -> SessionInfo:
        sid = f"s{next(self._session_ids)}"
        info = SessionInfo(session_id=sid, priority=priority, created_at=now)
        with self._lock:
            self._sessions[sid] = info
        return info

    def new_request(self, session_id: str) -> str:
        rid = f"r{next(self._request_ids)}"
        with self._lock:
            info = self._sessions.get(session_id)
            if info is not None:
                info.request_ids.append(rid)
        return rid

    def get(self, session_id: str) -> Optional[SessionInfo]:
        with self._lock:
            return self._sessions.get(session_id)

    def set_priority(self, session_id: str, value: float,
                     agent_type: Optional[str] = None) -> None:
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None:
                return
            if agent_type is None:
                info.priority = value
            else:
                info.agent_priority[agent_type] = value

    def close(self, session_id: str) -> None:
        with self._lock:
            info = self._sessions.get(session_id)
            if info is not None:
                info.active = False

    def all(self) -> List[SessionInfo]:
        with self._lock:
            return list(self._sessions.values())


# ------------------------------------------------------------- exec context
def set_context(session_id: str, request_id: str, caller: str) -> None:
    _ctx.session_id = session_id
    _ctx.request_id = request_id
    _ctx.caller = caller


def get_context() -> tuple:
    return (
        getattr(_ctx, "session_id", ""),
        getattr(_ctx, "request_id", ""),
        getattr(_ctx, "caller", "driver:anonymous"),
    )


def clear_context() -> None:
    for a in ("session_id", "request_id", "caller", "deadline"):
        if hasattr(_ctx, a):
            delattr(_ctx, a)


# The current code's absolute deadline (kernel time), or -1.0 when none.
# Stubs read it so child calls inherit the parent's *remaining* budget; the
# runtime sets it when entering an agent context (from the running future's
# metadata) and drivers seed it via ``submit_request(deadline_s=...)``.
def set_current_deadline(deadline: float) -> None:
    if deadline is None or deadline < 0:
        if hasattr(_ctx, "deadline"):
            delattr(_ctx, "deadline")
    else:
        _ctx.deadline = deadline


def get_current_deadline() -> float:
    return getattr(_ctx, "deadline", -1.0)
