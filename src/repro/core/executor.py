"""Agent/tool executors: instances, queues, latency models.

An *agent type* (e.g. ``developer``) has one or more *instances*
(``developer:node3/1``), each managed by a component-level controller.  Method
implementations come in three flavours:

* ``EmulatedMethod`` — a leaf component (LLM engine, vector store, web API)
  whose behaviour is a cheap Python ``value_fn`` and whose *cost* is a
  ``LatencyModel``.  Matches the paper's §6.3 methodology ("profiles LLM
  inference calls to mimic execution behavior").  Executed as a scheduled
  completion event — no thread.

* plain Python callables — composite agents whose body may itself invoke
  other agents/tools through stubs (Fig. 3).  Executed on a kernel driver
  thread; the instance stays busy for the whole span, which is exactly what
  produces the head-of-line blocking the paper's policies mitigate.

* ``EngineBackedMethod`` subclasses — leaf LLM calls executed on a *real*
  serving engine (``repro.serving.InferenceEngine`` via
  ``repro.serving.bridge.EngineMethod``).  The controller hands the future
  to the backend and moves on: the engine batches continuously on its own
  thread and resolves the future through a completion callback, so one
  instance carries up to ``capacity()`` in-flight futures at a time.  This
  is the real-execution counterpart of §6.3 emulation — same stub, same
  future, same routing; only the leaf executes for real.
"""

from __future__ import annotations

import math
import random
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .directives import Directives


# ------------------------------------------------------------ latency models
class LatencyModel:
    def service_time(self, hints: List[dict], rng: random.Random) -> float:
        """Virtual seconds to process a batch; ``hints`` has one entry per item."""
        raise NotImplementedError


@dataclass
class FixedLatency(LatencyModel):
    seconds: float

    def service_time(self, hints, rng) -> float:
        return self.seconds


@dataclass
class LognormalLatency(LatencyModel):
    median: float
    sigma: float = 0.5

    def service_time(self, hints, rng) -> float:
        return self.median * math.exp(rng.gauss(0.0, self.sigma))


@dataclass
class LLMLatency(LatencyModel):
    """Token-based LLM cost model (vLLM-style continuous batching).

    time = base + in_tokens/prefill_tps + out_tokens/decode_tps, with batched
    requests sharing the engine at ``batch_efficiency`` scaling: a batch of B
    takes max_item_time * (1 + (B-1)*(1-eff)) — eff=1 is perfect batching.
    """

    prefill_tps: float = 8000.0
    decode_tps: float = 60.0
    base: float = 0.05
    batch_efficiency: float = 0.85
    jitter_sigma: float = 0.08

    def _item_time(self, hint: dict, rng: random.Random) -> float:
        tin = hint.get("in_tokens", 512)
        tout = hint.get("out_tokens", 128)
        t = self.base + tin / self.prefill_tps + tout / self.decode_tps
        if self.jitter_sigma:
            t *= math.exp(rng.gauss(0.0, self.jitter_sigma))
        return t

    def service_time(self, hints, rng) -> float:
        if not hints:
            return self.base
        times = [self._item_time(h, rng) for h in hints]
        b = len(times)
        return max(times) * (1.0 + (b - 1) * (1.0 - self.batch_efficiency))


@dataclass
class EmulatedMethod:
    """Leaf method: value from ``value_fn``, cost from ``latency``."""

    latency: LatencyModel
    value_fn: Optional[Callable[..., Any]] = None

    def compute(self, *args, **kwargs) -> Any:
        if self.value_fn is None:
            return None
        return self.value_fn(*args, **kwargs)


class EngineBackedMethod:
    """Abstract async leaf method executed on an external serving engine.

    Contract with the component controller:

    * ``launch(batch, controller)`` is called with futures whose dependencies
      are already materialized; it must return quickly (submission only) and
      arrange for ``controller.complete_async(fut, value=..., error=...)``
      to be invoked exactly once per future, from any thread.
    * The instance is NOT considered blocked while engine calls are in
      flight; the controller keeps admitting work until ``capacity()``
      futures are running on this instance (the engine's own continuous
      batching replaces controller-side batching).

    The concrete implementation lives in ``repro.serving.bridge`` so that
    ``repro.core`` stays importable without JAX/serving dependencies.
    """

    def capacity(self) -> int:
        """Max futures in flight on one instance (engine batch width)."""
        return 8

    def launch(self, batch: List[Any], controller: Any) -> None:
        raise NotImplementedError


# ---------------------------------------------------------------- instances
@dataclass
class InstanceMetrics:
    completed: int = 0
    failed: int = 0
    # failure-handling telemetry: local re-attempts started here, futures
    # cancelled while queued/running here, and futures resolved
    # DeadlineExceeded at launch time here
    retries: int = 0
    cancelled: int = 0
    expired: int = 0
    busy_until: float = 0.0
    total_busy: float = 0.0
    queue_len: int = 0
    # exponential moving average of service time (global controller input)
    ema_service: float = 0.0
    last_latencies: List[float] = field(default_factory=list)

    def record_service(self, t: float) -> None:
        self.ema_service = 0.8 * self.ema_service + 0.2 * t if self.ema_service else t
        self.total_busy += t
        self.last_latencies.append(t)
        if len(self.last_latencies) > 64:
            self.last_latencies.pop(0)


class AgentInstance:
    """A running copy of an agent/tool on a node.

    Pure data + queue container; all *behaviour* lives in the component
    controller so the scheduling path is observable and policy-driven.
    """

    def __init__(self, agent_type: str, instance_id: str, node_id: str,
                 methods: Dict[str, Any], directives: Directives) -> None:
        self.agent_type = agent_type
        self.instance_id = instance_id        # "developer:n3/1"
        self.node_id = node_id
        self.methods = methods                # name -> EmulatedMethod | callable
        self.directives = directives
        self.queue: List[Any] = []            # ready futures awaiting dispatch
        self.running: List[Any] = []          # futures being executed now
        self.metrics = InstanceMetrics()
        self.alive = True
        self._lock = threading.RLock()
        # sessions with work waiting here (the HoL policy in Fig. 6 reads this)
        self.waiting_sessions: Dict[str, int] = {}

    # Queue ops are called only from the owning controller.
    def enqueue(self, fut) -> None:
        with self._lock:
            self.queue.append(fut)
            sid = fut.meta.session_id
            if sid:
                self.waiting_sessions[sid] = self.waiting_sessions.get(sid, 0) + 1
            self.metrics.queue_len = len(self.queue)

    def dequeue_selected(self, futs: List[Any]) -> None:
        with self._lock:
            for f in futs:
                self.queue.remove(f)
                sid = f.meta.session_id
                if sid and sid in self.waiting_sessions:
                    self.waiting_sessions[sid] -= 1
                    if self.waiting_sessions[sid] <= 0:
                        del self.waiting_sessions[sid]
            self.metrics.queue_len = len(self.queue)

    def remove_queued(self, fut) -> bool:
        with self._lock:
            if fut in self.queue:
                self.dequeue_selected([fut])
                return True
            return False

    def qsize(self) -> int:
        with self._lock:
            return len(self.queue)

    @property
    def busy(self) -> bool:
        with self._lock:
            return len(self.running) > 0

    def eta(self, now: float) -> float:
        """Estimated seconds until this instance is free (HoL signal).

        Emulated methods publish ``busy_until``; async engine-backed (and
        composite) methods don't, so in-flight futures are also charged at
        the EMA service rate — otherwise least-ETA routing is blind to a
        replica already carrying a full engine batch.
        """
        with self._lock:
            remaining = max(0.0, self.metrics.busy_until - now) if self.running else 0.0
            ema = max(self.metrics.ema_service, 1e-3)
            if self.running and remaining == 0.0:
                remaining = len(self.running) * ema
            return remaining + self.qsize() * ema

    def load_score(self, now: float) -> float:
        return self.eta(now)

    def __repr__(self) -> str:  # pragma: no cover
        return f"AgentInstance({self.instance_id}, q={self.qsize()}, busy={self.busy})"
