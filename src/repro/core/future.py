"""Futures as first-class runtime objects (paper §3.2, §4.3.1, Table 3).

A NALAR future represents a long-running agent-driven computation.  Its
*value* is immutable once materialized; its *metadata* (executor, consumers,
priority) is mutable, which is what enables late binding and migration of
already-routed work — the key departure from Ray/CIEL futures.

Three runtime operations (Fig. 7):
  Op 1  creation            non-blocking
  Op 2  register consumer   non-blocking (first value access registers caller)
  Op 3  return              ``value()`` blocks until push-based materialization

Readiness is push-based: when a future resolves, the producing component
controller immediately transfers the value to every registered consumer.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional

_future_ids = itertools.count()


class FutureState(str, Enum):
    PENDING = "pending"        # created, not yet dispatched/running
    SCHEDULED = "scheduled"    # routed to an executor queue
    RUNNING = "running"
    READY = "ready"            # value materialized
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclass
class FutureMetadata:
    """Mutable coordination metadata (Table 3)."""

    dependencies: List[str] = field(default_factory=list)   # future ids needed
    creator: str = ""          # "agent_name:instance_id" (or "driver:<rid>")
    executor: str = ""         # where the computation is slated to run
    consumers: List[str] = field(default_factory=list)      # who needs the value
    session_id: str = ""
    request_id: str = ""
    agent_type: str = ""       # agent/tool that computes this future
    method: str = ""
    priority: float = 0.0      # higher = more urgent
    created_at: float = 0.0
    scheduled_at: float = -1.0
    started_at: float = -1.0
    ready_at: float = -1.0
    # bookkeeping for emulated execution / cost models
    work_hint: Dict[str, Any] = field(default_factory=dict)


class Future:
    """Coordination handle returned by auto-generated stubs.

    Driver code interacts only via ``available`` and ``value`` (§3.2 API);
    everything else is runtime-internal.
    """

    __slots__ = (
        "fid", "meta", "_state", "_value", "_error", "_ready_evt",
        "_runtime", "_lock", "args", "kwargs",
    )

    def __init__(self, runtime: Any, meta: FutureMetadata,
                 args: tuple = (), kwargs: Optional[dict] = None) -> None:
        self.fid = f"f{next(_future_ids)}"
        self.meta = meta
        self._state = FutureState.PENDING
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._ready_evt = threading.Event()
        self._runtime = runtime
        self._lock = threading.Lock()
        self.args = args
        self.kwargs = kwargs or {}

    # ------------------------------------------------------------ public API
    @property
    def available(self) -> bool:
        """True iff the value is materialized (non-blocking)."""
        return self._state in (FutureState.READY, FutureState.FAILED)

    def value(self, timeout: Optional[float] = None) -> Any:
        """Blocking access (Op 3).  Registers the caller as a consumer."""
        if not self._ready_evt.is_set():
            self._runtime.register_consumer(self)
            ok = self._runtime.kernel.wait_event(self._ready_evt, timeout)
            if not ok:
                raise TimeoutError(f"future {self.fid} not ready within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    # ------------------------------------------------------- runtime-internal
    @property
    def state(self) -> FutureState:
        return self._state

    def _set_state(self, s: FutureState) -> None:
        self._state = s

    def materialize(self, value: Any, now: float) -> None:
        """Make the value available and push readiness to waiters.

        Value immutability: a second materialization is a runtime bug.
        """
        with self._lock:
            if self._state == FutureState.READY:
                raise RuntimeError(f"future {self.fid} materialized twice")
            self._value = value
            self._state = FutureState.READY
            self.meta.ready_at = now
        self._runtime.kernel.notify(self._ready_evt)

    def fail(self, error: BaseException, now: float) -> None:
        with self._lock:
            if self._state in (FutureState.READY, FutureState.FAILED):
                return
            self._error = error
            self._state = FutureState.FAILED
            self.meta.ready_at = now
        self._runtime.kernel.notify(self._ready_evt)

    def unresolved_deps(self, table: "FutureTable") -> List[str]:
        out = []
        for dep in self.meta.dependencies:
            f = table.get(dep)
            if f is not None and not f.available:
                out.append(dep)
        return out

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Future({self.fid}, {self.meta.agent_type}.{self.meta.method}, "
                f"{self._state.value}, exec={self.meta.executor})")


class FutureTable:
    """Process-wide registry mapping fid -> Future.

    In the distributed deployment this is sharded across node stores; the
    in-process table keeps one authoritative object per future while the node
    stores hold serialized metadata mirrors (what Fig. 10 measures).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._futures: Dict[str, Future] = {}

    def add(self, f: Future) -> None:
        with self._lock:
            self._futures[f.fid] = f

    def get(self, fid: str) -> Optional[Future]:
        with self._lock:
            return self._futures.get(fid)

    def remove(self, fid: str) -> None:
        with self._lock:
            self._futures.pop(fid, None)

    def __len__(self) -> int:
        with self._lock:
            return len(self._futures)

    def snapshot(self) -> List[Future]:
        with self._lock:
            return list(self._futures.values())


def resolve_args(args: tuple, kwargs: dict) -> tuple:
    """Replace Future objects in call args with their materialized values.

    Called by the executing component controller once all dependencies are
    ready (push-based: the values have already arrived).
    """
    def r(x: Any) -> Any:
        if isinstance(x, Future):
            assert x.available, "dependency not materialized before execution"
            return x.value()
        if isinstance(x, (list, tuple)):
            t = type(x)
            return t(r(i) for i in x)
        if isinstance(x, dict):
            return {k: r(v) for k, v in x.items()}
        return x

    return tuple(r(a) for a in args), {k: r(v) for k, v in kwargs.items()}


def extract_dependencies(args: tuple, kwargs: dict) -> List[str]:
    """Scan call arguments for Future objects (dynamic dep-graph extraction)."""
    deps: List[str] = []

    def scan(x: Any) -> None:
        if isinstance(x, Future):
            deps.append(x.fid)
        elif isinstance(x, (list, tuple)):
            for i in x:
                scan(i)
        elif isinstance(x, dict):
            for v in x.values():
                scan(v)

    for a in args:
        scan(a)
    for v in kwargs.values():
        scan(v)
    return deps
