"""Futures as first-class runtime objects (paper §3.2, §4.3.1, Table 3).

A NALAR future represents a long-running agent-driven computation.  Its
*value* is immutable once materialized; its *metadata* (executor, consumers,
priority) is mutable, which is what enables late binding and migration of
already-routed work — the key departure from Ray/CIEL futures.

Three runtime operations (Fig. 7):
  Op 1  creation            non-blocking
  Op 2  register consumer   non-blocking (first value access registers caller)
  Op 3  return              ``value()`` blocks until push-based materialization

Readiness is push-based: when a future resolves, the producing component
controller immediately transfers the value to every registered consumer.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional

_future_ids = itertools.count()


class FutureState(str, Enum):
    PENDING = "pending"        # created, not yet dispatched/running
    SCHEDULED = "scheduled"    # routed to an executor queue
    RUNNING = "running"
    READY = "ready"            # value materialized
    FAILED = "failed"
    CANCELLED = "cancelled"


#: states in which a future is resolved and will never run again
TERMINAL_STATES = (FutureState.READY, FutureState.FAILED,
                   FutureState.CANCELLED)


class FutureCancelled(RuntimeError):
    """Raised by ``Future.value()`` when the future was cancelled.

    Cancellation is a *terminal* resolution: consumers are notified exactly
    like on failure, but the retry ladder never re-dispatches a cancelled
    future."""


class InstanceDied(RuntimeError):
    """The agent instance executing a future died (fault injection, replica
    crash, hard ``kill_instance``).  Component controllers escalate this
    error straight to the global controller — local in-place retries are
    pointless on a dead executor."""


class DeadlineExceeded(RuntimeError):
    """The future's deadline passed before (or while) it executed.

    Like cancellation this is a *terminal* resolution: the work is worthless
    after the deadline, so the retry ladder never re-dispatches an expired
    future and no retry budget is burned."""


@dataclass
class FutureMetadata:
    """Mutable coordination metadata (Table 3)."""

    dependencies: List[str] = field(default_factory=list)   # future ids needed
    creator: str = ""          # "agent_name:instance_id" (or "driver:<rid>")
    executor: str = ""         # where the computation is slated to run
    consumers: List[str] = field(default_factory=list)      # who needs the value
    session_id: str = ""
    request_id: str = ""
    agent_type: str = ""       # agent/tool that computes this future
    method: str = ""
    priority: float = 0.0      # higher = more urgent
    # absolute deadline in kernel time; -1.0 = none.  Stamped at creation
    # (min of the call's own budget and the caller's inherited remaining
    # budget) and enforced at launch, at engine admission, and mid-decode.
    deadline: float = -1.0
    created_at: float = 0.0
    scheduled_at: float = -1.0
    started_at: float = -1.0
    ready_at: float = -1.0
    # failure-handling bookkeeping: attempt 0 is the first execution, each
    # retry (local or escalated) increments it; ``escalations`` counts hops
    # through the global controller's RetryPolicy ladder
    attempt: int = 0
    escalations: int = 0
    # bookkeeping for emulated execution / cost models
    work_hint: Dict[str, Any] = field(default_factory=dict)
    # every node whose store holds (or held) this future's metadata mirror —
    # migration/escalation re-home the mirror, and GC must scrub them all
    mirror_nodes: List[str] = field(default_factory=list)


class Future:
    """Coordination handle returned by auto-generated stubs.

    Driver code interacts only via ``available`` and ``value`` (§3.2 API);
    everything else is runtime-internal.
    """

    __slots__ = (
        "fid", "meta", "_state", "_value", "_error", "_ready_evt",
        "_runtime", "_lock", "args", "kwargs", "_run_id",
        "_table", "_live_indexed",
        "_chunks", "_chunk_gen", "_stream_tokens", "_chunk_evt",
        "_stream_owner",
    )

    def __init__(self, runtime: Any, meta: FutureMetadata,
                 args: tuple = (), kwargs: Optional[dict] = None) -> None:
        self.fid = f"f{next(_future_ids)}"
        self.meta = meta
        self._state = FutureState.PENDING
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._ready_evt = threading.Event()
        self._runtime = runtime
        self._lock = threading.Lock()
        self.args = args
        self.kwargs = kwargs or {}
        # execution fence: bumped every time a controller moves the future
        # into RUNNING.  Completion callbacks captured under an older run id
        # are stale (the attempt was preempted, retried, or its instance
        # died) and must not resolve the future.
        self._run_id = 0
        # the FutureTable tracking this future's liveness (set by add());
        # _live_indexed — mutated only under the table's lock — records
        # whether this future currently contributes to the table's
        # per-session live counters and secondary indexes
        self._table: Optional["FutureTable"] = None
        self._live_indexed = False
        # ---- incremental streaming (STREAMING sub-state of RUNNING) ----
        # Append-only chunk log for the CURRENT attempt.  Each entry is a
        # list of token ids emitted by one engine step.  A retry truncates
        # the log back to the attempt boundary (all entries belong to the
        # attempt that appended them — exactly-once mirrors state epochs)
        # and bumps ``_chunk_gen`` so live iterators rewind their cursor.
        self._chunks: List[list] = []
        self._chunk_gen = 0
        self._stream_tokens = 0          # total tokens across self._chunks
        # eventcount: replaced with a fresh Event on every append/terminal
        # transition; waiters capture it under the lock, then block on it
        self._chunk_evt = threading.Event()
        # stream ownership: the first producer (engine instance id) to
        # append claims the stream; a concurrently-running hedge duplicate
        # shares the run id, so the run fence alone cannot stop it from
        # interleaving tokens — owner mismatch rejects its appends
        self._stream_owner: Optional[str] = None

    # ------------------------------------------------------------ public API
    @property
    def available(self) -> bool:
        """True iff the future is resolved (non-blocking): value materialized,
        failed, or cancelled."""
        return self._state in TERMINAL_STATES

    def value(self, timeout: Optional[float] = None) -> Any:
        """Blocking access (Op 3).  Registers the caller as a consumer."""
        if not self._ready_evt.is_set():
            self._runtime.register_consumer(self)
            ok = self._runtime.kernel.wait_event(self._ready_evt, timeout)
            if not ok:
                raise TimeoutError(f"future {self.fid} not ready within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    # ------------------------------------------------------------- streaming
    @property
    def streaming(self) -> bool:
        """True while partial output exists but the future is unresolved —
        the STREAMING sub-state of RUNNING (orthogonal to the lifecycle
        enum: materialize/fail/cancel/retry machinery is unchanged)."""
        return bool(self._chunks) and self._state not in TERMINAL_STATES

    def streamed(self) -> int:
        """Tokens streamed so far in the current attempt (non-blocking)."""
        return self._stream_tokens

    def partial(self) -> list:
        """Snapshot of all tokens streamed so far (current attempt only).

        Non-blocking; valid in any state.  After READY the log has been
        sealed to the full output, so ``partial()`` equals the final token
        sequence for engine-backed calls."""
        with self._lock:
            out: list = []
            for c in self._chunks:
                out.extend(c)
            return out

    def wait_streamed(self, n: int, timeout: Optional[float] = None) -> int:
        """Block until ≥ ``n`` tokens have streamed or the future resolves.

        Returns the streamed count (callers should check ``available`` on
        return: terminal resolution also wakes this wait, so a short answer
        or a failure returns with fewer than ``n`` tokens).  ``timeout``
        bounds each successive wait for progress."""
        self._runtime.register_consumer(self)
        while True:
            with self._lock:
                if (self._stream_tokens >= n
                        or self._state in TERMINAL_STATES):
                    return self._stream_tokens
                evt = self._chunk_evt
            if not self._runtime.kernel.wait_event(evt, timeout):
                raise TimeoutError(
                    f"future {self.fid}: no stream progress within {timeout}s")

    def iter_chunks(self, timeout: Optional[float] = None):
        """Yield token chunks in order until the future resolves.

        Terminates cleanly at READY (after draining the sealed log) and
        raises the stored error at FAILED/CANCELLED, so consumers blocked
        mid-stream observe a drain/cancel as a fast failure instead of a
        hang.  A mid-stream retry truncates the log back to the attempt
        boundary; live iterators detect the generation bump and rewind to
        re-observe the fresh attempt (greedy decode re-streams identical
        tokens).  ``timeout`` bounds the wait for each successive chunk."""
        self._runtime.register_consumer(self)
        i = 0
        gen = self._chunk_gen
        while True:
            with self._lock:
                if gen != self._chunk_gen:      # retry rewound the log
                    gen = self._chunk_gen
                    i = 0
                if i < len(self._chunks):
                    chunk, evt = self._chunks[i], None
                    i += 1
                elif self._state in TERMINAL_STATES:
                    chunk, evt = None, False
                else:
                    chunk, evt = None, self._chunk_evt
            if evt is None:
                yield chunk
            elif evt is False:
                if self._error is not None:
                    raise self._error
                return
            elif not self._runtime.kernel.wait_event(evt, timeout):
                raise TimeoutError(
                    f"future {self.fid}: no chunk within {timeout}s")

    def append_chunk(self, chunk: list, now: float = 0.0,
                     expect_run: Optional[int] = None,
                     owner: str = "") -> bool:
        """Append one engine step's tokens to the stream (runtime-internal).

        Fenced twice: ``expect_run`` rejects appends captured under a
        superseded attempt (retry/preemption), and ``owner`` rejects a
        hedge duplicate racing the stream's first producer (hedges share
        the run id, so the run fence alone cannot order them).  Returns
        False when the append was rejected or the future is resolved."""
        with self._lock:
            if self._state in TERMINAL_STATES:
                return False
            if expect_run is not None and self._run_id != expect_run:
                return False
            if owner:
                if self._stream_owner is None:
                    self._stream_owner = owner
                elif self._stream_owner != owner:
                    return False
            self._chunks.append(list(chunk))
            self._stream_tokens += len(chunk)
            evt, self._chunk_evt = self._chunk_evt, threading.Event()
        self._runtime.kernel.notify(evt)
        notify_partial = getattr(self._runtime, "on_future_partial", None)
        if notify_partial is not None:
            notify_partial(self)
        return True

    def seal_stream(self, tokens: list, owner: str = "",
                    expect_run: Optional[int] = None) -> None:
        """Reconcile the chunk log with the final token sequence.

        Called by the winning completion just before materialization: the
        common case appends the not-yet-streamed tail as a last chunk.  If
        the log disagrees with ``tokens`` (a hedge loser streamed first and
        claimed ownership), it is truncated and replaced wholesale — the
        generation bump makes live iterators rewind onto the winner's
        tokens, so the stream a consumer assembles is always byte-identical
        to the completion value."""
        with self._lock:
            if self._state in TERMINAL_STATES:
                return
            if expect_run is not None and self._run_id != expect_run:
                return
            have: list = []
            for c in self._chunks:
                have.extend(c)
            if ((owner and self._stream_owner not in (None, owner))
                    or have != list(tokens[:len(have)])):
                self._chunks.clear()
                self._stream_tokens = 0
                self._chunk_gen += 1
                have = []
            if owner:
                self._stream_owner = owner
            tail = list(tokens[len(have):])
            if tail:
                self._chunks.append(tail)
                self._stream_tokens += len(tail)
            evt, self._chunk_evt = self._chunk_evt, threading.Event()
        self._runtime.kernel.notify(evt)

    def _wake_stream_waiters_locked(self) -> threading.Event:
        """Swap in a fresh chunk event; caller must hold ``_lock`` and
        notify the returned event after releasing it."""
        evt, self._chunk_evt = self._chunk_evt, threading.Event()
        return evt

    # ------------------------------------------------------- runtime-internal
    @property
    def state(self) -> FutureState:
        return self._state

    def _set_state(self, s: FutureState) -> None:
        self._state = s

    def materialize(self, value: Any, now: float) -> None:
        """Make the value available and push readiness to waiters.

        Value immutability: a second materialization is a runtime bug.  A
        materialization racing a cancellation loses silently — the caller
        renounced the value, so the late result is discarded.
        """
        with self._lock:
            if self._state == FutureState.READY:
                raise RuntimeError(f"future {self.fid} materialized twice")
            if self._state == FutureState.CANCELLED:
                return
            self._value = value
            self._state = FutureState.READY
            self.meta.ready_at = now
            chunk_evt = self._wake_stream_waiters_locked()
        self._notify_resolved()
        self._runtime.kernel.notify(chunk_evt)
        self._runtime.kernel.notify(self._ready_evt)

    def fail(self, error: BaseException, now: float) -> None:
        with self._lock:
            if self._state in TERMINAL_STATES:
                return
            self._error = error
            self._state = FutureState.FAILED
            self.meta.ready_at = now
            chunk_evt = self._wake_stream_waiters_locked()
        self._notify_resolved()
        self._runtime.kernel.notify(chunk_evt)
        self._runtime.kernel.notify(self._ready_evt)

    def cancel(self, now: float, reason: str = "cancelled") -> bool:
        """Resolve the future as CANCELLED; waiters raise ``FutureCancelled``.

        Returns False when the future is already resolved.  Queue removal and
        consumer notification are orchestrated by ``runtime.cancel_future`` /
        the executor's component controller — this method only flips the
        handle's state and wakes blocked ``value()`` callers.
        """
        with self._lock:
            if self._state in TERMINAL_STATES:
                return False
            self._error = FutureCancelled(
                f"future {self.fid} ({self.meta.agent_type}.{self.meta.method}) "
                f"cancelled: {reason}")
            self._state = FutureState.CANCELLED
            self.meta.ready_at = now
            chunk_evt = self._wake_stream_waiters_locked()
        self._notify_resolved()
        self._runtime.kernel.notify(chunk_evt)
        self._runtime.kernel.notify(self._ready_evt)
        return True

    def reset_for_retry(self, now: float) -> bool:
        """FAILED/SCHEDULED/RUNNING -> PENDING reset for retry re-dispatch.

        Increments the attempt counter and re-arms readiness so the future
        can travel the dispatch path again.  READY and CANCELLED futures are
        immutable — the reset is refused.
        """
        with self._lock:
            if self._state in (FutureState.READY, FutureState.CANCELLED):
                return False
            revived = self._state == FutureState.FAILED
            self._error = None
            self._state = FutureState.PENDING
            self.meta.attempt += 1
            # close the fence immediately: a completion captured under the
            # superseded attempt must not land during the PENDING
            # backoff/escalation window either
            self._run_id += 1
            self.meta.scheduled_at = -1.0
            self.meta.started_at = -1.0
            self.meta.ready_at = -1.0
            if self._ready_evt.is_set():
                # the future had terminally failed (its waiters already woke
                # and observed the error); new waiters need a fresh event
                self._ready_evt = threading.Event()
            # truncate the stream back to the attempt boundary: every
            # logged chunk belongs to the superseded attempt, so the retry
            # re-streams from scratch.  The generation bump rewinds live
            # iterators; waking them here lets blocked consumers observe
            # the rewind instead of waiting on a dead event.
            if self._chunks:
                self._chunks.clear()
                self._stream_tokens = 0
                self._chunk_gen += 1
            self._stream_owner = None
            chunk_evt = self._wake_stream_waiters_locked()
        self._runtime.kernel.notify(chunk_evt)
        if revived:
            self._notify_revived()
        return True

    # liveness notifications keep the FutureTable's per-session counters and
    # secondary indexes exact at every state transition — called with the
    # future's own lock RELEASED (lock order: future lock before table lock,
    # never interleaved)
    def _notify_resolved(self) -> None:
        if self._table is not None:
            self._table.on_resolved(self)

    def _notify_revived(self) -> None:
        if self._table is not None:
            self._table.on_revived(self)

    def unresolved_deps(self, table: "FutureTable") -> List[str]:
        out = []
        for dep in self.meta.dependencies:
            f = table.get(dep)
            if f is not None and not f.available:
                out.append(dep)
        return out

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Future({self.fid}, {self.meta.agent_type}.{self.meta.method}, "
                f"{self._state.value}, exec={self.meta.executor})")


class FutureTable:
    """Process-wide registry mapping fid -> Future.

    In the distributed deployment this is sharded across node stores; the
    in-process table keeps one authoritative object per future while the node
    stores hold serialized metadata mirrors (what Fig. 10 measures).

    The table is *bounded*: once it grows past ``gc_threshold`` entries, a
    sweep retires resolved futures (READY/FAILED/CANCELLED).  Resolved
    futures have already pushed their values to every registered consumer,
    and dependency checks treat a missing fid as resolved, so retirement is
    invisible to the runtime — it just keeps long-running deployments
    (the 130K-future scale of ``fig10_control_loop``) memory-flat.  Callers
    holding the ``Future`` object keep full access to its value.

    The table is *indexed*: per-session live-future counters plus by-session
    / by-executor / by-agent-type secondary indexes, maintained at future
    state transitions (materialize/fail/cancel/reset_for_retry notify the
    table; GC and explicit removal reconcile through the same per-future
    ``_live_indexed`` flag, so tombstoned epochs, run-id-fenced completions
    and retry re-arms all keep the counters exact).  This is what lets the
    global controller answer "which sessions still have unresolved work" in
    O(1) per session instead of an O(N) snapshot per control round.
    """

    def __init__(self, gc_threshold: int = 4096) -> None:
        self._lock = threading.Lock()
        self._futures: Dict[str, Future] = {}
        # sweep trigger; 0/None disables GC entirely
        self.gc_threshold = gc_threshold
        self.retired = 0          # total futures GC'd over the table's life
        # adaptive watermark: when a sweep finds little to collect (a burst
        # of still-pending futures), back off geometrically so future
        # creation stays amortized O(1) instead of O(n) per add
        self._sweep_floor = 0
        # secondary indexes (all under _lock):
        self._by_session: Dict[str, Dict[str, Future]] = {}   # all registered
        self._live_by_session: Dict[str, int] = {}            # live counters
        self._live_by_executor: Dict[str, Dict[str, Future]] = {}
        self._live_by_type: Dict[str, Dict[str, Future]] = {}
        # sessions whose liveness flipped (0 <-> >0) since the last drain;
        # the global controller re-filters stale waiting lists from this
        self._dirty_sessions: set = set()

    # ------------------------------------------------------- index internals
    def _index_live_locked(self, f: Future) -> None:
        if f._live_indexed:
            return
        f._live_indexed = True
        sid = f.meta.session_id
        if sid:
            before = self._live_by_session.get(sid, 0)
            self._live_by_session[sid] = before + 1
            if before == 0:
                self._dirty_sessions.add(sid)
        if f.meta.executor:
            self._live_by_executor.setdefault(f.meta.executor, {})[f.fid] = f
        if f.meta.agent_type:
            self._live_by_type.setdefault(f.meta.agent_type, {})[f.fid] = f

    def _unindex_live_locked(self, f: Future) -> None:
        if not f._live_indexed:
            return
        f._live_indexed = False
        sid = f.meta.session_id
        if sid:
            after = self._live_by_session.get(sid, 1) - 1
            if after <= 0:
                self._live_by_session.pop(sid, None)
                self._dirty_sessions.add(sid)
            else:
                self._live_by_session[sid] = after
        for index, key in ((self._live_by_executor, f.meta.executor),
                           (self._live_by_type, f.meta.agent_type)):
            bucket = index.get(key)
            if bucket is not None:
                bucket.pop(f.fid, None)
                if not bucket:
                    index.pop(key, None)

    def on_resolved(self, f: Future) -> None:
        """A registered future reached a terminal state."""
        with self._lock:
            self._unindex_live_locked(f)

    def on_revived(self, f: Future) -> None:
        """A FAILED future was re-armed (``reset_for_retry``)."""
        with self._lock:
            if f.fid in self._futures and not f.available:
                self._index_live_locked(f)

    def set_executor(self, f: Future, instance_id: str) -> None:
        """Re-home ``f``'s executor, keeping the by-executor index exact.
        All executor reassignment (submit, migration, reroute) goes through
        here."""
        with self._lock:
            if f._live_indexed and f.meta.executor != instance_id:
                bucket = self._live_by_executor.get(f.meta.executor)
                if bucket is not None:
                    bucket.pop(f.fid, None)
                    if not bucket:
                        self._live_by_executor.pop(f.meta.executor, None)
                f.meta.executor = instance_id
                if instance_id:
                    self._live_by_executor.setdefault(
                        instance_id, {})[f.fid] = f
            else:
                f.meta.executor = instance_id

    # ------------------------------------------------------------ index API
    def live_count(self, session_id: str) -> int:
        """Unresolved futures of ``session_id`` — O(1)."""
        with self._lock:
            return self._live_by_session.get(session_id, 0)

    def live_sessions(self) -> set:
        """Sessions with at least one unresolved future — O(live sessions)."""
        with self._lock:
            return set(self._live_by_session)

    def drain_dirty_sessions(self) -> set:
        """Sessions whose liveness flipped since the last drain (single
        consumer: the global controller's incremental view maintenance)."""
        with self._lock:
            out = self._dirty_sessions
            self._dirty_sessions = set()
            return out

    def futures_of_session(self, session_id: str) -> List[Future]:
        """Every registered (not yet GC'd) future of the session."""
        with self._lock:
            return list(self._by_session.get(session_id, {}).values())

    def live_of_executor(self, instance_id: str) -> List[Future]:
        with self._lock:
            return list(self._live_by_executor.get(instance_id, {}).values())

    def live_of_type(self, agent_type: str) -> List[Future]:
        with self._lock:
            return list(self._live_by_type.get(agent_type, {}).values())

    # -------------------------------------------------------------- registry
    def add(self, f: Future) -> None:
        f._table = self
        with self._lock:
            self._futures[f.fid] = f
            sid = f.meta.session_id
            if sid:
                self._by_session.setdefault(sid, {})[f.fid] = f
            if not f.available:
                self._index_live_locked(f)

    def get(self, fid: str) -> Optional[Future]:
        with self._lock:
            return self._futures.get(fid)

    def _forget_locked(self, f: Future) -> None:
        self._unindex_live_locked(f)
        sid = f.meta.session_id
        if sid:
            bucket = self._by_session.get(sid)
            if bucket is not None:
                bucket.pop(f.fid, None)
                if not bucket:
                    self._by_session.pop(sid, None)

    def remove(self, fid: str) -> None:
        with self._lock:
            f = self._futures.pop(fid, None)
            if f is not None:
                self._forget_locked(f)

    def __len__(self) -> int:
        with self._lock:
            return len(self._futures)

    def snapshot(self) -> List[Future]:
        with self._lock:
            return list(self._futures.values())

    def needs_sweep(self) -> bool:
        if not self.gc_threshold:
            return False
        with self._lock:
            return len(self._futures) > max(self.gc_threshold,
                                            self._sweep_floor)

    def sweep(self) -> List[Future]:
        """Retire resolved futures; returns them (for mirror cleanup).

        Retirement never touches the live counters directly: resolution
        already decremented them (``on_resolved``), and ``_forget_locked``
        only reconciles a future whose resolution raced the sweep — so a
        completed-then-GC'd future decrements its session exactly once.
        """
        with self._lock:
            dead = [f for f in self._futures.values()
                    if f.state in TERMINAL_STATES]
            for f in dead:
                del self._futures[f.fid]
                self._forget_locked(f)
            self.retired += len(dead)
            # next sweep only once the table doubles past what survived —
            # collapses back to gc_threshold as soon as futures resolve
            self._sweep_floor = max(self.gc_threshold,
                                    2 * len(self._futures))
        return dead


def resolve_args(args: tuple, kwargs: dict,
                 stream_min: Optional[int] = None) -> tuple:
    """Replace Future objects in call args with their materialized values.

    Called by the executing component controller once all dependencies are
    ready (push-based: the values have already arrived).

    ``stream_min`` is the consumer's ``stream_min_tokens`` hint: a still-
    running dependency that has streamed at least that many tokens is
    substituted with its ``partial()`` token snapshot instead of blocking —
    the consumer declared it can start on partial output.  Fully-resolved
    dependencies substitute their value as usual (callers accepting partial
    input must handle both shapes).
    """
    def r(x: Any) -> Any:
        if isinstance(x, Future):
            if not x.available and stream_min is not None:
                partial = x.partial()
                assert len(partial) >= stream_min, (
                    "partial dependency dispatched below stream_min_tokens")
                return partial
            assert x.available, "dependency not materialized before execution"
            return x.value()
        if isinstance(x, (list, tuple)):
            t = type(x)
            return t(r(i) for i in x)
        if isinstance(x, dict):
            return {k: r(v) for k, v in x.items()}
        return x

    return tuple(r(a) for a in args), {k: r(v) for k, v in kwargs.items()}


def extract_dependencies(args: tuple, kwargs: dict) -> List[str]:
    """Scan call arguments for Future objects (dynamic dep-graph extraction)."""
    deps: List[str] = []

    def scan(x: Any) -> None:
        if isinstance(x, Future):
            deps.append(x.fid)
        elif isinstance(x, (list, tuple)):
            for i in x:
                scan(i)
        elif isinstance(x, dict):
            for v in x.values():
                scan(v)

    for a in args:
        scan(a)
    for v in kwargs.values():
        scan(v)
    return deps
