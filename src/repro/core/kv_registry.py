"""K,V-cache residency registry (paper §4.3.2).

NALAR tracks futures, so it knows which requests are pending or likely to
arrive, and can hint the LLM serving layer which K,V caches to retain, evict,
or migrate — the LMCache-hook mechanism in the paper.  This registry is the
agent-layer side of that contract; ``repro.serving.kv_cache`` consumes the
hints on the TPU side (HBM-resident paged cache with per-session page tables).

Hints are advisory; the serving layer remains correct if it ignores them —
it just falls back to generic LRU like vanilla vLLM/SGLang.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Tuple


class Residency(str, Enum):
    DEVICE = "device"        # keep in HBM (GPU memory in the paper)
    FAR = "far"              # offload to host/far memory
    DROP = "drop"            # safe to evict


@dataclass
class SessionCacheInfo:
    session_id: str
    instance_id: str                  # engine instance holding the cache
    tokens: int = 0                   # cached prefix length
    residency: Residency = Residency.DEVICE
    pinned_until: float = 0.0         # retain-hint deadline
    last_used: float = 0.0


class KVRegistry:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._sessions: Dict[str, SessionCacheInfo] = {}
        # serving-layer callbacks: instance_id -> hook(session_id, hint)
        self._hooks: Dict[str, Callable[[str, str], None]] = {}
        # reuse-decision telemetry: how often the agent layer found a warm
        # cache when preparing a call (consumed by the engine bridge)
        self.stats: Dict[str, int] = {"reuse_queries": 0, "reuse_hits": 0,
                                      "reuse_tokens": 0}

    # ------------------------------------------------------------ bookkeeping
    def touch(self, session_id: str, instance_id: str, tokens: int,
              now: float) -> None:
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None or info.instance_id != instance_id:
                info = SessionCacheInfo(session_id, instance_id)
                self._sessions[session_id] = info
            info.tokens = max(info.tokens, tokens)
            info.last_used = now

    def lookup(self, session_id: str) -> Optional[SessionCacheInfo]:
        with self._lock:
            return self._sessions.get(session_id)

    def cached_tokens(self, session_id: str, instance_id: str) -> int:
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None or info.instance_id != instance_id:
                return 0
            if info.residency == Residency.DROP:
                return 0
            return info.tokens

    def expect_reuse(self, session_id: str, instance_id: str) -> int:
        """Like ``cached_tokens`` but records the query in ``stats`` — the
        agent layer calls this when deciding whether a follow-up can be sent
        as a continuation suffix (warm cache) or needs its full context
        rebuilt (cold)."""
        tokens = self.cached_tokens(session_id, instance_id)
        with self._lock:
            self.stats["reuse_queries"] += 1
            if tokens > 0:
                self.stats["reuse_hits"] += 1
                self.stats["reuse_tokens"] += tokens
        return tokens

    def instance_sessions(self, instance_id: str) -> List[str]:
        """Sessions whose cache currently resides on ``instance_id``."""
        with self._lock:
            return [s for s, i in self._sessions.items()
                    if i.instance_id == instance_id
                    and i.residency != Residency.DROP]

    def residency_map(self) -> Dict[str, Tuple[str, int]]:
        """session_id -> (instance holding its cache, cached token count).

        The global controller snapshots this into ``ClusterView.kv_residency``
        so policies can express KV-affinity with the plain ``route``
        primitive (see ``policy.KVAffinityPolicy``).  Dropped caches are
        excluded — a released session has no affinity."""
        with self._lock:
            return {s: (i.instance_id, i.tokens)
                    for s, i in self._sessions.items()
                    if i.residency != Residency.DROP}

    # ----------------------------------------------------------------- hints
    def register_hook(self, instance_id: str,
                      hook: Callable[[str, str], None]) -> None:
        with self._lock:
            self._hooks[instance_id] = hook

    def _fire(self, instance_id: str, session_id: str, hint: str) -> None:
        hook = self._hooks.get(instance_id)
        if hook is not None:
            hook(session_id, hint)

    def retain(self, session_id: str, until: float) -> None:
        """Global-controller hint: this session's cache will be reused soon."""
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None:
                return
            info.pinned_until = max(info.pinned_until, until)
            inst = info.instance_id
        self._fire(inst, session_id, "retain")

    def release(self, session_id: str) -> None:
        """Session ended: the cache may be evicted immediately."""
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None:
                return
            info.residency = Residency.DROP
            info.pinned_until = 0.0
            inst = info.instance_id
        self._fire(inst, session_id, "drop")

    def offload(self, session_id: str) -> None:
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None:
                return
            info.residency = Residency.FAR
            inst = info.instance_id
        self._fire(inst, session_id, "offload")

    # -------------------------------------------------------------- migration
    def migrate(self, session_id: str, src_instance: str,
                dst_instance: str) -> int:
        """Move cache ownership; returns migrated token count (cost model)."""
        with self._lock:
            info = self._sessions.get(session_id)
            if info is None or info.instance_id != src_instance:
                return 0
            info.instance_id = dst_instance
            tokens = info.tokens
        self._fire(src_instance, session_id, "migrate_out")
        self._fire(dst_instance, session_id, "migrate_in")
        return tokens

    def eviction_candidates(self, instance_id: str, now: float) -> List[str]:
        """Sessions safe to evict on this instance (not pinned), LRU order."""
        with self._lock:
            cands = [i for i in self._sessions.values()
                     if i.instance_id == instance_id and i.pinned_until <= now
                     and i.residency != Residency.DROP]
        return [i.session_id for i in sorted(cands, key=lambda i: i.last_used)]
