"""Component-level controller: event-driven local enforcement (paper §4.1).

One controller per agent instance.  Three roles (verbatim from the paper):
 1. local scheduling with policies installed by the global controller, plus
    maintenance of future metadata for migration and value propagation;
 2. the interface between stubs and the runtime — stubs invoke the controller,
    never user code directly;
 3. serving-time metrics (queue length, latencies, resource use) pushed to the
    node store for the global controller's periodic computations.

Migration (Fig. 8) is coordinated entirely among component controllers; the
global controller only issues the command.
"""

from __future__ import annotations

import random
import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from .executor import AgentInstance, EmulatedMethod, EngineBackedMethod
from .future import (DeadlineExceeded, Future, FutureCancelled, FutureState,
                     InstanceDied, TERMINAL_STATES, resolve_args)


class LocalSchedule:
    """Local scheduling policy installed by the global controller.

    ``order_key(fut, now)``: smaller runs first.  Default: priority then FCFS.
    Swappable at runtime via the policy interface (§4.2) — e.g. SRTF installs
    a remaining-work key, LPT a longest-processing-time key.
    """

    name = "priority_fcfs"

    def order_key(self, fut: Future, now: float):
        return (-fut.meta.priority, fut.meta.created_at)


class ComponentController:
    def __init__(self, runtime, instance: AgentInstance) -> None:
        self.runtime = runtime
        self.inst = instance
        self.kernel = runtime.kernel
        self.store = runtime.stores.get(instance.node_id)
        self.schedule_policy: LocalSchedule = LocalSchedule()
        # stable across processes (str hash is salted; crc32 is not)
        import zlib
        self._rng = random.Random(zlib.crc32(instance.instance_id.encode()))
        self._lock = threading.RLock()
        # futures parked here waiting on dependencies: fid -> set of dep fids
        self._parked: Dict[str, set] = {}
        # metrics-mirror write coalescing: inside a ``_metrics_batch`` block
        # (one per externally-triggered pump iteration) publishes only mark
        # the mirror dirty; one ``hset_many`` lands at batch exit.  Depth is
        # per-thread (nesting == call-stack), the dirty flag is shared —
        # a racing flush publishes the freshest state either way.
        self._pub_tls = threading.local()
        self._pub_dirty = False
        self._publish_metrics()
        # consume policy/commands written to the node store asynchronously
        self.store.subscribe(f"cmd:{instance.instance_id}", self._on_command)

    @contextmanager
    def _metrics_batch(self):
        depth = getattr(self._pub_tls, "depth", 0)
        self._pub_tls.depth = depth + 1
        try:
            yield
        finally:
            self._pub_tls.depth = depth
            if depth == 0 and self._pub_dirty:
                self._flush_metrics()

    # ------------------------------------------------------------ submission
    def submit(self, fut: Future) -> None:
        """A stub routed ``fut`` here.  Park until deps ready, then enqueue."""
        if not self.inst.alive:
            # instance died between routing and arrival: re-route
            self.runtime.dispatch(fut)
            return
        # executor reassignment goes through the table so its by-executor
        # index stays exact
        self.runtime.futures.set_executor(fut, self.inst.instance_id)
        fut.meta.scheduled_at = self.kernel.now()
        fut._set_state(FutureState.SCHEDULED)
        pending = set(fut.unresolved_deps(self.runtime.futures))
        # a consumer declared with stream_min_tokens can start on partial
        # output: deps that have already streamed enough don't park it
        smin = fut.meta.work_hint.get("stream_min_tokens")
        if pending and smin is not None:
            for dep_fid in list(pending):
                dep = self.runtime.futures.get(dep_fid)
                if dep is not None and dep.streamed() >= int(smin):
                    pending.discard(dep_fid)
        with self._metrics_batch():
            with self._lock:
                if pending:
                    self._parked[fut.fid] = pending
                    for dep in pending:
                        self.runtime.register_dep_consumer(dep, self)
                else:
                    self._enqueue(fut)
            self._maybe_dispatch()

    def on_dep_ready(self, dep_fid: str) -> None:
        """Push-based readiness: a producer transferred a dependency value."""
        ready: List[Future] = []
        with self._lock:
            for fid, deps in list(self._parked.items()):
                deps.discard(dep_fid)
                if not deps:
                    del self._parked[fid]
                    fut = self.runtime.futures.get(fid)
                    if fut is not None:
                        ready.append(fut)
        with self._metrics_batch():
            for fut in ready:
                with self._lock:
                    self._enqueue(fut)
            if ready:
                self._maybe_dispatch()

    def on_dep_partial(self, dep_fid: str, streamed: int) -> None:
        """Partial availability: a streaming producer appended a chunk.

        Parked futures whose ``stream_min_tokens`` hint is satisfied treat
        the dependency as ready-enough and dispatch; ``resolve_args`` then
        substitutes the dep's ``partial()`` snapshot at execution time."""
        ready: List[Future] = []
        with self._lock:
            for fid, deps in list(self._parked.items()):
                if dep_fid not in deps:
                    continue
                fut = self.runtime.futures.get(fid)
                if fut is None:
                    continue
                smin = fut.meta.work_hint.get("stream_min_tokens")
                if smin is None or streamed < int(smin):
                    continue
                deps.discard(dep_fid)
                if not deps:
                    del self._parked[fid]
                    ready.append(fut)
        with self._metrics_batch():
            for fut in ready:
                with self._lock:
                    self._enqueue(fut)
            if ready:
                self._maybe_dispatch()

    def _enqueue(self, fut: Future) -> None:
        self.inst.enqueue(fut)
        self._publish_metrics()

    # -------------------------------------------------------------- dispatch
    def _maybe_dispatch(self) -> None:
        with self._lock:
            if not self.inst.alive or self.inst.qsize() == 0:
                return
            now = self.kernel.now()
            order = sorted(self.inst.queue, key=lambda f: self.schedule_policy.order_key(f, now))
            head = order[0]
            method = self.inst.methods.get(head.meta.method)
            if isinstance(method, EngineBackedMethod):
                # Engine-backed leaves are asynchronous: the external engine
                # batches continuously, so the instance admits work until the
                # engine's batch width is saturated instead of blocking on
                # one in-flight batch.
                free = max(1, method.capacity()) - len(self.inst.running)
                if free <= 0:
                    return
                batch = [f for f in order if f.meta.method == head.meta.method][:free]
                self.inst.dequeue_selected(batch)
                self.inst.running.extend(batch)
            else:
                if self.inst.busy:
                    return
                batch = [head]
                if self.inst.directives.batchable:
                    for f in order[1:]:
                        if len(batch) >= self.inst.directives.max_batch:
                            break
                        if f.meta.method == head.meta.method:
                            batch.append(f)
                self.inst.dequeue_selected(batch)
                self.inst.running = list(batch)
            # re-publish after dequeue: otherwise the node-store mirror keeps
            # claiming these sessions are *waiting* here until completion,
            # and a policy round in between acts on the stale list
            self._publish_metrics()
        self._execute(batch)

    def _execute(self, batch: List[Future]) -> None:
        now = self.kernel.now()
        # launch-time deadline check: work whose deadline already passed is
        # worthless — resolve it DeadlineExceeded (terminal, never retried)
        # instead of burning the executor on it
        expired = [f for f in batch
                   if 0 <= f.meta.deadline <= now]
        if expired:
            batch = [f for f in batch if f not in expired]
            for f in expired:
                self.inst.metrics.expired += 1
                self._complete(f, error=DeadlineExceeded(
                    f"future {f.fid} ({f.meta.agent_type}.{f.meta.method}) "
                    f"deadline {f.meta.deadline:.3f} passed at launch "
                    f"(now {now:.3f})"))
            if not batch:
                return
        for f in batch:
            f._set_state(FutureState.RUNNING)
            f._run_id += 1      # fences stale completions of older attempts
            f.meta.started_at = now
        method = self.inst.methods.get(batch[0].meta.method)
        if isinstance(method, EngineBackedMethod):
            self._execute_engine(batch, method)
        elif isinstance(method, EmulatedMethod):
            self._execute_emulated(batch, method)
        elif callable(method):
            self._execute_composite(batch[0], method)
        else:
            for f in batch:
                self._complete(f, error=AttributeError(
                    f"{self.inst.agent_type} has no method {f.meta.method}"))

    def _execute_emulated(self, batch: List[Future], method: EmulatedMethod) -> None:
        # enrich hints with execution context so cost models can consult
        # session-state (e.g. K,V-cache locality — §4.3.2)
        hints = [dict(f.meta.work_hint,
                      session_id=f.meta.session_id,
                      instance=self.inst.instance_id,
                      now=self.kernel.now()) for f in batch]
        service = method.latency.service_time(hints, self._rng)
        now = self.kernel.now()
        self.inst.metrics.busy_until = now + service
        self.inst.metrics.record_service(service)
        runs = [(f, f._run_id) for f in batch]

        def finish() -> None:
            done_any = False
            for f, run_id in runs:
                if f.state != FutureState.RUNNING or f._run_id != run_id:
                    # preempted/migrated/retried mid-flight — or the losing
                    # half of a hedged pair (already resolved elsewhere).
                    # A resolved loser occupied this instance until *now*,
                    # so only now does its running entry clear (migrated /
                    # retried futures are live elsewhere: leave them alone)
                    if f.available:
                        self.detach_running(f)
                    continue
                done_any = True
                if f.meta.executor != self.inst.instance_id:
                    # hedged duplicate completing first: attribute the win
                    # to the instance that actually produced the value
                    self.runtime.futures.set_executor(
                        f, self.inst.instance_id)
                try:
                    self.runtime.enter_agent_context(f, self.inst)
                    args, kwargs = resolve_args(
                        f.args, f.kwargs,
                        stream_min=f.meta.work_hint.get("stream_min_tokens"))
                    value = method.compute(*args, **kwargs)
                    self._complete(f, value=value)
                except BaseException as e:  # noqa: BLE001 — fault reporting (§5)
                    self._complete(f, error=e)
                finally:
                    self.runtime.exit_agent_context()
            if not done_any:
                # entire batch was preempted away; free the instance
                self._maybe_dispatch()

        self.kernel.schedule(service, finish, tag=f"exec:{self.inst.instance_id}")

    def _execute_engine(self, batch: List[Future],
                        method: "EngineBackedMethod") -> None:
        """Hand the batch to a real serving engine; completions arrive later
        via ``complete_async`` from the engine's pump thread."""
        try:
            method.launch(batch, self)
        except BaseException as e:  # noqa: BLE001 — submission failure (§5)
            for f in batch:
                self.complete_async(f, error=e)

    def complete_async(self, fut: Future, value: Any = None,
                       error: Optional[BaseException] = None,
                       expect_run: Optional[int] = None) -> None:
        """Thread-safe completion entry for asynchronous backends.

        Routed through ``kernel.schedule`` so that, under the SimKernel, the
        completion becomes an ordinary event (deterministic ordering) and,
        under the RealTimeKernel, it fires on a timer thread rather than
        re-entering the caller's stack.

        A future cancelled (or otherwise resolved) while in flight on an
        engine must NOT be materialized by the late completion; callers that
        captured ``expect_run`` at submission additionally fence against the
        future having been retried on another replica in the meantime.
        """
        def finish() -> None:
            if fut.state in TERMINAL_STATES:
                return  # preempted/cancelled/failed while in flight
            if expect_run is not None and fut._run_id != expect_run:
                return  # stale completion of a superseded attempt
            self.inst.metrics.record_service(
                max(0.0, self.kernel.now() - fut.meta.started_at))
            self._complete(fut, value=value, error=error)

        self.kernel.schedule(0.0, finish, tag=f"engine-done:{fut.fid}")

    def _execute_composite(self, fut: Future, fn) -> None:
        """User-code agent method that may itself call stubs: run on a driver
        thread so nested future blocking works under virtual time."""
        run_id = fut._run_id

        def body() -> None:
            start = self.kernel.now()
            try:
                self.runtime.enter_agent_context(fut, self.inst)
                args, kwargs = resolve_args(
                    fut.args, fut.kwargs,
                    stream_min=fut.meta.work_hint.get("stream_min_tokens"))
                value = fn(*args, **kwargs)
                err: Optional[BaseException] = None
            except BaseException as e:  # noqa: BLE001
                value, err = None, e
            finally:
                self.runtime.exit_agent_context()
            self.inst.metrics.record_service(self.kernel.now() - start)
            if err is None:
                self._complete(fut, value=value, expect_run=run_id)
            else:
                self._complete(fut, error=err, expect_run=run_id)

        self.kernel.spawn_driver(body, name=f"agent:{fut.fid}")

    # ------------------------------------------------------------ completion
    def _complete(self, fut: Future, value: Any = None,
                  error: Optional[BaseException] = None,
                  expect_run: Optional[int] = None) -> None:
        # one coalesced metrics write per completion, not one per intermediate
        # publish point (dequeue, failure bookkeeping, re-dispatch)
        with self._metrics_batch():
            self._complete_inner(fut, value, error, expect_run)

    def _complete_inner(self, fut: Future, value: Any = None,
                        error: Optional[BaseException] = None,
                        expect_run: Optional[int] = None) -> None:
        now = self.kernel.now()
        with self._lock:
            if fut in self.inst.running:
                self.inst.running.remove(fut)
        if expect_run is not None and fut._run_id != expect_run:
            # stale completion of a superseded attempt: the future was
            # preempted/retried and re-executes elsewhere; drop the result
            self._publish_metrics()
            self._maybe_dispatch()
            return
        epoch = (fut.fid, fut.meta.attempt)
        if fut.state == FutureState.CANCELLED:
            # resolved by cancellation while in flight: discard the late
            # result; the cancel path already rolled back + notified
            self.runtime.state_store.rollback_epoch(epoch)
            self._publish_metrics()
            self._maybe_dispatch()
            return
        if fut.state in (FutureState.READY, FutureState.FAILED):
            # already resolved — the winning half of a hedged pair got here
            # first; drop the loser's late result (its epoch was never opened:
            # only leaf methods may race, and leaves journal no state)
            self._publish_metrics()
            self._maybe_dispatch()
            return
        if error is not None:
            # failed attempt: its managed-state writes never happened
            # (exactly-once contract — rollback precedes any re-execution)
            self.runtime.state_store.rollback_epoch(epoch)
            if self._handle_failure(fut, error, now):
                self._publish_metrics()
                self._maybe_dispatch()
                return          # absorbed: retrying locally or escalated
            self.inst.metrics.failed += 1
            fut.fail(error, now)
        else:
            self.runtime.state_store.commit_epoch(epoch)
            self.inst.metrics.completed += 1
            fut.materialize(value, now)
        self._push_consumers(fut)
        self.runtime.on_future_resolved(fut)
        self.runtime.telemetry.on_future_done(fut, self.inst, now)
        self._publish_metrics()
        self._maybe_dispatch()

    def _push_consumers(self, fut: Future) -> None:
        """Push resolution to each consumer controller (push-based readiness)."""
        for consumer in list(fut.meta.consumers):
            ctrl = self.runtime.controller_of(consumer)
            if ctrl is not None and ctrl is not self:
                delay = self.runtime.net_latency(self.inst.node_id, ctrl.inst.node_id)
                self.kernel.schedule(delay, lambda c=ctrl, fid=fut.fid: c.on_dep_ready(fid))
            elif ctrl is self:
                self.on_dep_ready(fut.fid)

    # ------------------------------------------------------- failure handling
    def _retry_budget(self, fut: Future) -> int:
        """Per-call retry budget.

        ``_hint={"max_retries": n}`` overrides the agent directive outright
        (0 disables retries for this call).  The pre-existing ``"retry"``
        hint doubles as the budget only when truthy — drivers tag first
        attempts of their own retry loops with ``{"retry": 0}`` as a
        *scheduling* signal (LPT re-entrance), which must not silently
        disable the operator's ``max_retries`` directive.
        """
        hint = fut.meta.work_hint
        for key, zero_counts in (("max_retries", True), ("retry", False)):
            v = hint.get(key)
            if v is None:
                continue
            try:
                n = int(v)
            except (TypeError, ValueError):
                continue
            if n > 0 or (zero_counts and n == 0):
                return max(0, n)
        return self.inst.directives.max_retries

    def _retryable(self, error: BaseException) -> bool:
        r = self.inst.directives.retryable
        if callable(r):
            try:
                return bool(r(error))
            except Exception:  # noqa: BLE001 — a broken predicate fails fast
                return False
        return bool(r)

    def _handle_failure(self, fut: Future, error: BaseException,
                        now: float) -> bool:
        """The retry ladder (rung 1 + handoff to rung 2).

        Returns True when the failure was absorbed: either a local in-place
        retry was scheduled (backoff), or the future escalated to the global
        controller's RetryPolicy (budget exhausted / instance death).  False
        means the failure is terminal and the caller should ``fail`` it.
        """
        if isinstance(error, (FutureCancelled, DeadlineExceeded)):
            # cancellation is never retried; expired work is worthless after
            # its deadline — neither burns retry budget
            return False
        budget = self._retry_budget(fut)
        if budget <= 0 or not self._retryable(error):
            return False
        dead = not self.inst.alive or isinstance(error, InstanceDied)
        if not dead and fut.meta.attempt < budget:
            self._schedule_retry(fut, now)
            return True
        # rung 2: local budget exhausted, or the executor died — hand the
        # future to the global controller for rerouting to a survivor
        return self.runtime.escalate(
            fut, error, self.inst.instance_id,
            reason="instance_death" if dead else "budget_exhausted")

    def _schedule_retry(self, fut: Future, now: float) -> None:
        """Rung 1: retry in place with exponential backoff."""
        delay = self.inst.directives.retry_backoff * (2 ** fut.meta.attempt)
        if not fut.reset_for_retry(now):
            return
        self.inst.metrics.retries += 1

        def resubmit() -> None:
            if fut.state != FutureState.PENDING:
                return          # cancelled during backoff
            if self.inst.alive:
                self.submit(fut)
            else:
                self.runtime.dispatch(fut)   # died during backoff: re-route

        self.kernel.schedule(delay, resubmit, tag=f"retry:{fut.fid}")

    def cancel_local(self, fut: Future, reason: str) -> bool:
        """Cancel a future owned by this controller: remove it from queued /
        parked / running bookkeeping, resolve it CANCELLED, and propagate
        readiness so dependents unblock (they observe the cancellation when
        they touch the value)."""
        now = self.kernel.now()
        with self._lock:
            self.inst.remove_queued(fut)
            self._parked.pop(fut.fid, None)
            if fut in self.inst.running:
                self.inst.running.remove(fut)
        if not fut.cancel(now, reason):
            return False
        # a running attempt may have written managed state already
        self.runtime.state_store.rollback_epoch((fut.fid, fut.meta.attempt))
        self.inst.metrics.cancelled += 1
        with self._metrics_batch():
            self._push_consumers(fut)
            self.runtime.on_future_resolved(fut)
            self.runtime.telemetry.on_future_done(fut, self.inst, now)
            self._publish_metrics()
            self._maybe_dispatch()
        return True

    # ------------------------------------------------------------- migration
    def take_session_futures(self, session_id: str) -> List[Future]:
        """Atomically remove and return this session's queued futures.

        Used by ``serving.pool.EnginePool`` migration to hand a session's
        not-yet-launched work to the destination replica without reaching
        into the queue's bookkeeping (``waiting_sessions`` stays coherent).
        """
        with self._lock:
            futs = [f for f in list(self.inst.queue)
                    if f.meta.session_id == session_id]
            if futs:
                self.inst.dequeue_selected(futs)
        if futs:
            self._publish_metrics()
        return futs

    def detach_running(self, fut: Future) -> None:
        """Drop ``fut`` from the running set (engine-pool re-route: the
        future never reached the engine and is being re-submitted on
        another replica)."""
        with self._lock:
            if fut in self.inst.running:
                self.inst.running.remove(fut)
        self._publish_metrics()

    def migrate_out(self, fut: Future, dst_instance_id: str) -> bool:
        """Fig. 8 protocol, steps 2–6, coordinated locally.

        Returns True if migration happened (future was still movable here).
        """
        dst_ctrl = self.runtime.controller_of(dst_instance_id)
        if dst_ctrl is None:
            return False
        with self._lock:
            queued = self.inst.remove_queued(fut)
            parked = fut.fid in self._parked
            if parked:
                pending = self._parked.pop(fut.fid)
            if not queued and not parked:
                # running: movable only if the agent declared `preemptable`
                # (Table 1) — preemption-with-restart semantics: the pending
                # completion event becomes a no-op (state check) and the
                # future re-executes at the destination.
                preempt_fn = self.inst.directives.preemptable
                if (preempt_fn is None or fut not in self.inst.running
                        or len(self.inst.running) != 1):
                    return False
                self.inst.running.remove(fut)
                fut._set_state(FutureState.PENDING)
                fut.meta.work_hint["preempted"] = \
                    fut.meta.work_hint.get("preempted", 0) + 1
                if callable(preempt_fn):
                    preempt_fn(fut)
                queued = True   # treat as movable from here on
        now = self.kernel.now()
        # Step 2+3: for unresolved deps, repoint the consumer registration so
        # producers push values to the destination instead of here.
        if parked and pending:
            for dep in pending:
                self.runtime.register_dep_consumer(dep, dst_ctrl)
        # Step 4: notify creator that the executor changed (metadata update,
        # routed through the table to keep the by-executor index exact).
        self.runtime.futures.set_executor(fut, dst_instance_id)
        self.runtime.telemetry.on_migration(fut, self.inst.instance_id,
                                            dst_instance_id, now)
        # Step 5: transfer session state; cost modelled as a delay on activation.
        bytes_moved = self.runtime.migrate_session_state(
            fut.meta.session_id, self.inst.agent_type, dst_ctrl.inst.node_id)
        delay = self.runtime.state_transfer_delay(
            self.inst.node_id, dst_ctrl.inst.node_id, bytes_moved)
        # also move KV-cache residency hints for the session (§4.3.2)
        self.runtime.kv_registry.migrate(fut.meta.session_id,
                                         self.inst.instance_id, dst_instance_id)

        # Step 6: activate at destination.
        def activate() -> None:
            if parked and pending:
                with dst_ctrl._lock:
                    # a dep retired by the FutureTable GC counts as resolved
                    deps = {d: self.runtime.futures.get(d) for d in pending}
                    still = {d for d, f in deps.items()
                             if f is not None and not f.available}
                    if still:
                        dst_ctrl._parked[fut.fid] = still
                    else:
                        dst_ctrl._enqueue(fut)
                dst_ctrl._maybe_dispatch()
            else:
                dst_ctrl.submit(fut)

        self.kernel.schedule(delay, activate, tag="migrate-activate")
        self._publish_metrics()
        return True

    def migrate_session(self, session_id: str, dst_instance_id: str) -> int:
        """Move a session to another instance (Table 2 ``migrate``).

        Engine-pool agent types delegate to the pool backend, which owns the
        physical semantics: defer past the in-flight engine call, replay the
        transcript on the destination, re-home the KV registry, then move
        queued futures.  Emulated/composite agents keep the seed behaviour —
        move all queued/parked futures of the session.
        """
        backend = self.runtime.engine_backends.get(self.inst.agent_type)
        if backend is not None and hasattr(backend, "migrate_session"):
            return backend.migrate_session(session_id,
                                           self.inst.instance_id,
                                           dst_instance_id)
        with self._lock:
            movable = [f for f in list(self.inst.queue)
                       if f.meta.session_id == session_id]
            movable += [self.runtime.futures.get(fid)
                        for fid, _ in list(self._parked.items())
                        if self.runtime.futures.get(fid) is not None
                        and self.runtime.futures.get(fid).meta.session_id == session_id]
        n = 0
        for f in movable:
            if f is not None and self.migrate_out(f, dst_instance_id):
                n += 1
        return n

    # ----------------------------------------------------- commands & policy
    def _on_command(self, field: str, payload: Any) -> None:
        """Commands written by the global controller into the node store."""
        kind = payload.get("kind") if isinstance(payload, dict) else None
        if kind == "migrate_session":
            self.migrate_session(payload["session_id"], payload["dst"])
        elif kind == "migrate_future":
            fut = self.runtime.futures.get(payload["fid"])
            if fut is not None:
                self.migrate_out(fut, payload["dst"])
        elif kind == "set_schedule":
            self.schedule_policy = payload["policy"]
            self._maybe_dispatch()
        elif kind == "kill":
            self.shutdown(drain_to=payload.get("drain_to"))

    def shutdown(self, drain_to: Optional[str] = None,
                 hard: bool = False) -> None:
        """Stop this instance.

        Graceful (default): queued and parked work drains to ``drain_to`` or
        re-routes through the runtime; in-flight work is allowed to finish
        (its completion events still fire).  ``hard=True`` models instance
        *death* (fault injection): in-flight work is lost — each running
        future fails with ``InstanceDied`` and travels the retry ladder
        (escalating to the global controller when retries are enabled).
        Engine-backed in-flight futures are failed by the serving backend's
        ``on_replica_killed`` hook instead, which also recovers the dead
        replica's sessions by transcript replay.
        """
        self.inst.alive = False
        with self._metrics_batch():
            with self._lock:
                pending = list(self.inst.queue)
                parked = [self.runtime.futures.get(fid)
                          for fid in list(self._parked)]
            # drain queued AND parked work; fall back to re-routing through
            # the runtime when no explicit drain target was given
            for f in pending + [p for p in parked if p is not None]:
                if drain_to and self.migrate_out(f, drain_to):
                    continue
                with self._lock:
                    dequeued = self.inst.remove_queued(f)
                    if f.fid in self._parked:
                        self._parked.pop(f.fid)
                        dequeued = True
                if dequeued:
                    self.runtime.dispatch(f)
            if hard:
                with self._lock:
                    running = list(self.inst.running)
                err = InstanceDied(f"instance {self.inst.instance_id} died")
                for f in running:
                    if isinstance(self.inst.methods.get(f.meta.method),
                                  EngineBackedMethod):
                        continue   # failed by the backend's on_replica_killed
                    self._complete(f, error=err)
            self._publish_metrics()

    # -------------------------------------------------------------- metrics
    def _publish_metrics(self) -> None:
        """Publish the metrics mirror — or, inside a ``_metrics_batch``
        block, mark it dirty for one coalesced write at batch exit."""
        if getattr(self._pub_tls, "depth", 0) > 0:
            self._pub_dirty = True
        else:
            self._flush_metrics()

    def _flush_metrics(self) -> None:
        m = self.inst.metrics
        self._pub_dirty = False
        # engine-backed instances piggyback data-plane gauges (wait-queue
        # depth / admission saturation) into the same mirror write, so the
        # global controller's InstanceView sees backpressure building
        # before the queue hard-rejects (duck-typed: core never imports
        # serving)
        extra: Dict[str, Any] = {}
        backend = self.runtime.engine_backends.get(self.inst.agent_type)
        if backend is not None and hasattr(backend, "instance_metrics"):
            try:
                extra = dict(backend.instance_metrics(
                    self.inst.instance_id) or {})
            except Exception:  # noqa: BLE001 — telemetry must never wedge
                extra = {}
        self.store.hset_many(f"metrics:{self.inst.instance_id}", {
            **extra,
            "agent_type": self.inst.agent_type,
            "node": self.inst.node_id,
            "qsize": self.inst.qsize(),
            "busy": self.inst.busy,
            "inflight": len(self.inst.running),
            "busy_until": m.busy_until,
            "ema_service": m.ema_service,
            "completed": m.completed,
            "failed": m.failed,
            "retries": m.retries,
            "cancelled": m.cancelled,
            "expired": m.expired,
            "alive": self.inst.alive,
            "waiting_sessions": list(self.inst.waiting_sessions),
            "updated_at": self.kernel.now(),
        })
