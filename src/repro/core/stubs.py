"""Auto-generated stubs: the conduit between user programs and NALAR (§3.1).

Before deployment, developers run the stub-generation tool on each agent or
tool with a short declaration (agent name, callable functions, parameters).
The generated module's methods do not execute the underlying logic; they
create and return *futures* carrying the call's metadata, which the runtime
schedules, routes, and monitors.

Two entry points:

* ``AgentSpec`` + ``generate_stub`` — programmatic declaration (what the YAML
  tool would emit);
* ``parse_spec`` — a minimal parser for the paper's YAML declaration format
  (PyYAML-free; the declarations are flat).

Stub calls strip an optional ``_hint`` kwarg ({"in_tokens", "out_tokens",
"est_service", "graph_depth", "retry", "max_retries", "deadline_s", ...})
used by cost models and scheduling policies — never seen by user code.  Two hints feed
the runtime's retry ladder: ``"max_retries"`` is the explicit per-call
budget (overrides the agent directive; 0 disables retries for this call),
and a *truthy* ``"retry"`` doubles as the budget for convenience —
``{"retry": 0}`` stays a pure scheduling signal (LPT re-entrance for
driver-managed retry loops) and leaves the directive in force.

Streaming hints: ``"stream_min_tokens": n`` declares the call can start on
partial input — the controller dispatches it as soon as every Future
dependency has streamed ≥ n tokens (the dep substitutes its ``partial()``
token snapshot; a dep that resolves first substitutes its value as usual).
``"session_id"`` overrides the context session for this one call, detaching
it from the caller's per-session ordering — a pipelined side-step (e.g. a
classifier racing its upstream generator) must not queue behind the very
call it consumes partial output from.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .directives import Directives
from .executor import EmulatedMethod
from .future import Future, FutureMetadata, extract_dependencies
from .session import get_context, get_current_deadline


@dataclass
class AgentSpec:
    """What the YAML declaration describes."""

    name: str
    # method name -> EmulatedMethod (leaf) | Python callable (composite)
    methods: Dict[str, Any] = field(default_factory=dict)
    directives: Directives = field(default_factory=Directives)

    def validate(self) -> None:
        if not self.name or not self.methods:
            raise ValueError("agent spec needs a name and >=1 callable function")
        self.directives.validate()


def parse_spec(text: str, impls: Dict[str, Any]) -> AgentSpec:
    """Parse the flat YAML declaration the stub tool consumes.

    Example::

        name: developer
        functions:
          - implement_and_test
          - review
        batchable: true
        max_instances: 4

    ``impls`` maps function names to their implementations (the tool links
    them at deployment; here they're passed directly).
    """
    name = ""
    functions: List[str] = []
    d = Directives()
    in_functions = False
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("#"):
            continue
        stripped = line.strip()
        if in_functions and stripped.startswith("- "):
            functions.append(stripped[2:].strip())
            continue
        in_functions = False
        if ":" not in stripped:
            raise ValueError(f"bad spec line: {raw!r}")
        key, _, val = stripped.partition(":")
        key, val = key.strip(), val.strip()
        if key == "name":
            name = val
        elif key == "functions":
            in_functions = True
        elif key in ("stateful", "batchable"):
            setattr(d, key, val.lower() in ("true", "1", "yes"))
        elif key in ("max_instances", "min_instances", "max_batch",
                     "max_retries"):
            setattr(d, key, int(val))
        elif key == "retry_backoff":
            d.retry_backoff = float(val)
        elif key == "resources":
            # "GPU=2,CPU=1"
            d.resources = {k: float(v) for k, v in
                           (kv.split("=") for kv in val.split(",") if kv)}
    missing = [f for f in functions if f not in impls]
    if missing:
        raise ValueError(f"no implementation linked for: {missing}")
    return AgentSpec(name=name,
                     methods={f: impls[f] for f in functions},
                     directives=d)


class Stub:
    """The importable module the stub tool generates for one agent/tool.

    Methods mirror the declared functions; each call creates a future, routes
    it via the caller's component controller, and returns immediately.
    """

    def __init__(self, runtime, spec: AgentSpec) -> None:
        self._runtime = runtime
        self._spec = spec
        for m in spec.methods:
            setattr(self, m, self._make_method(m))

    @property
    def agent_type(self) -> str:
        return self._spec.name

    def init(self, **directive_overrides) -> None:
        """Runtime directives at deployment time (Fig. 4 lines 6-7)."""
        self._runtime.apply_directives(self._spec.name, directive_overrides)

    def _make_method(self, method: str) -> Callable[..., Future]:
        def call(*args, **kwargs) -> Future:
            hint = kwargs.pop("_hint", {}) or {}
            sid, rid, caller = get_context()
            if hint.get("session_id") is not None:
                sid = str(hint["session_id"])
            rt = self._runtime
            now = rt.kernel.now()
            sess = rt.sessions.get(sid)
            prio = sess.priority_for(self._spec.name) if sess else 0.0
            # effective deadline = min(own budget, caller's remaining budget).
            # The inherited deadline is already absolute (the parent's), so a
            # child can never outlive its parent's budget; a tighter per-call
            # ``deadline_s`` (hint or directive) shrinks it further.
            budget = hint.get("deadline_s", self._spec.directives.deadline_s)
            deadline = get_current_deadline()
            if budget is not None and budget >= 0:
                own = now + float(budget)
                deadline = own if deadline < 0 else min(deadline, own)
            meta = FutureMetadata(
                dependencies=extract_dependencies(args, kwargs),
                creator=caller,
                session_id=sid,
                request_id=rid,
                agent_type=self._spec.name,
                method=method,
                priority=prio,
                deadline=deadline,
                created_at=now,
                work_hint=dict(hint),
            )
            fut = Future(rt, meta, args, kwargs)
            rt.add_future(fut)
            rt.dispatch(fut)
            return fut

        call.__name__ = method
        return call

    def __repr__(self) -> str:  # pragma: no cover
        return f"Stub({self._spec.name}, methods={list(self._spec.methods)})"


def emulated(latency, value_fn: Optional[Callable] = None) -> EmulatedMethod:
    """Shorthand for declaring a leaf method."""
    return EmulatedMethod(latency=latency, value_fn=value_fn)
