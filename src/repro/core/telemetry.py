"""Per-session/per-request telemetry and introspective debugging (paper §5).

NALAR has complete visibility into inter-agent calls, so it keeps detailed
per-session logs: time in each stage, agents/tools touched per node, failures
with workflow path + traceback.  The benchmark harness reads request records
to compute the latency distributions of Fig. 9.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class FutureRecord:
    fid: str
    agent_type: str
    method: str
    session_id: str
    request_id: str
    created_at: float
    scheduled_at: float
    started_at: float
    ready_at: float
    executor: str
    failed: bool
    cancelled: bool = False
    # which attempt resolved the future (0 = first execution; >0 means the
    # retry ladder re-dispatched it — rendered as ``retry#n`` in traces)
    attempt: int = 0

    @property
    def queue_time(self) -> float:
        return max(0.0, self.started_at - self.created_at)

    @property
    def service_time(self) -> float:
        return max(0.0, self.ready_at - self.started_at)


@dataclass
class RequestRecord:
    request_id: str
    session_id: str
    submitted_at: float
    finished_at: float = -1.0
    failed: bool = False
    # end-to-end budget the request was submitted with (-1 = none) and the
    # real outcome: the driver failed DeadlineExceeded, or completed after
    # the budget ran out.  Benchmarks report this instead of inferring
    # "unfinished == timed out".
    deadline_s: float = -1.0
    deadline_exceeded: bool = False
    # when the request's FIRST streamed chunk reached a future (-1 = the
    # request never streamed).  Workload-level TTFT: engines stamp their own
    # per-request first_token_at, but that never left per-engine metrics.
    first_output_at: float = -1.0
    stages: List[FutureRecord] = field(default_factory=list)

    @property
    def latency(self) -> float:
        return self.finished_at - self.submitted_at if self.finished_at >= 0 else -1.0

    @property
    def ttft(self) -> float:
        return (self.first_output_at - self.submitted_at
                if self.first_output_at >= 0 else -1.0)


@dataclass
class MigrationRecord:
    fid: str
    src: str
    dst: str
    at: float


@dataclass
class ControlRoundRecord:
    """One global-controller round: wall-clock breakdown plus how much state
    actually moved (``n_collected`` — the churn a delta round paid for) and
    whether the round was a full view rebuild (bootstrap / escape hatch)."""

    at: float                 # virtual time of the round
    collect: float            # wall-clock seconds
    policy: float
    push: float
    n_collected: int
    rebuild: bool

    @property
    def total(self) -> float:
        return self.collect + self.policy + self.push


class Telemetry:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.requests: Dict[str, RequestRecord] = {}
        self.migrations: List[MigrationRecord] = []
        # bounded: a long-lived deployment ticks forever (interval=0.25s ->
        # ~345K rounds/day); keep a rolling window, like the FutureTable
        # keeps the deployment memory-flat
        self.control_rounds: "deque[ControlRoundRecord]" = deque(maxlen=4096)
        self.futures_done = 0

    def start_request(self, request_id: str, session_id: str, now: float,
                      deadline_s: float = -1.0) -> None:
        with self._lock:
            self.requests[request_id] = RequestRecord(
                request_id, session_id, now, deadline_s=deadline_s)

    def end_request(self, request_id: str, now: float, failed: bool = False,
                    deadline_exceeded: bool = False) -> None:
        with self._lock:
            r = self.requests.get(request_id)
            if r is not None:
                r.finished_at = now
                r.failed = failed
                r.deadline_exceeded = deadline_exceeded

    def on_first_output(self, request_id: str, now: float) -> None:
        """Stamp TTFT from the first streamed chunk (idempotent: only the
        earliest stamp sticks — later chunks and hedge duplicates no-op)."""
        with self._lock:
            r = self.requests.get(request_id)
            if r is not None and r.first_output_at < 0:
                r.first_output_at = now

    def deadline_outcomes(self) -> Dict[str, float]:
        """Real per-request deadline accounting: requests submitted with a
        budget, how many missed it (failed DeadlineExceeded or finished
        late), how many never finished at all — plus workload-level TTFT
        percentiles from the streamed first-chunk stamps."""
        with self._lock:
            recs = list(self.requests.values())
        with_deadline = [r for r in recs if r.deadline_s >= 0]
        ttfts = sorted(r.ttft for r in recs if r.first_output_at >= 0)

        def pct(p: float) -> float:
            if not ttfts:
                return float("nan")
            return ttfts[min(len(ttfts) - 1,
                             int(round(p / 100.0 * (len(ttfts) - 1))))]

        return {
            "requests": len(recs),
            "with_deadline": len(with_deadline),
            "deadline_missed": sum(r.deadline_exceeded for r in recs),
            "unfinished": sum(r.finished_at < 0 for r in recs),
            "ttft_n": len(ttfts),
            "ttft_p50": pct(50),
            "ttft_p99": pct(99),
        }

    def on_future_done(self, fut, inst, now: float) -> None:
        rec = FutureRecord(
            fid=fut.fid, agent_type=fut.meta.agent_type, method=fut.meta.method,
            session_id=fut.meta.session_id, request_id=fut.meta.request_id,
            created_at=fut.meta.created_at, scheduled_at=fut.meta.scheduled_at,
            started_at=fut.meta.started_at, ready_at=now,
            executor=fut.meta.executor, failed=fut.state.value == "failed",
            cancelled=fut.state.value == "cancelled",
            attempt=fut.meta.attempt)
        with self._lock:
            self.futures_done += 1
            r = self.requests.get(fut.meta.request_id)
            if r is not None:
                r.stages.append(rec)

    def on_migration(self, fut, src: str, dst: str, now: float) -> None:
        with self._lock:
            self.migrations.append(MigrationRecord(fut.fid, src, dst, now))

    def on_control_round(self, at: float, collect: float, policy: float,
                         push: float, n_collected: int,
                         rebuild: bool) -> None:
        with self._lock:
            self.control_rounds.append(ControlRoundRecord(
                at, collect, policy, push, n_collected, rebuild))

    def control_summary(self) -> Dict[str, float]:
        """Mean per-stage wall-clock of the control loop (Fig. 10 shape)."""
        with self._lock:
            rounds = list(self.control_rounds)
        if not rounds:
            return {"rounds": 0}
        n = len(rounds)
        return {
            "rounds": n,
            "rebuilds": sum(r.rebuild for r in rounds),
            "collect_ms": 1e3 * sum(r.collect for r in rounds) / n,
            "policy_ms": 1e3 * sum(r.policy for r in rounds) / n,
            "push_ms": 1e3 * sum(r.push for r in rounds) / n,
            "mean_collected": sum(r.n_collected for r in rounds) / n,
        }

    # ------------------------------------------------------------- analysis
    def completed_latencies(self) -> List[float]:
        with self._lock:
            return sorted(r.latency for r in self.requests.values()
                          if r.finished_at >= 0 and not r.failed)

    def percentile(self, p: float) -> float:
        lat = self.completed_latencies()
        if not lat:
            return float("nan")
        idx = min(len(lat) - 1, int(round(p / 100.0 * (len(lat) - 1))))
        return lat[idx]

    def summary(self) -> Dict[str, float]:
        lat = self.completed_latencies()
        if not lat:
            return {"n": 0}
        return {
            "n": len(lat),
            "avg": sum(lat) / len(lat),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "max": lat[-1],
            "migrations": len(self.migrations),
        }

    def trace(self, request_id: str) -> Optional[RequestRecord]:
        """Workflow path for one request — the §5 debuggability hook."""
        with self._lock:
            return self.requests.get(request_id)
