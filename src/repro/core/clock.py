"""Virtual- and real-time event kernels for the NALAR runtime.

NALAR's control plane is event-driven (component controllers) plus periodic
(global controller).  The original system runs on wall-clock time across real
GPU nodes; this reproduction supports two interchangeable kernels:

* ``SimKernel`` — a deterministic discrete-event kernel.  Executors and
  controllers are pure event handlers; *driver programs* (ordinary Python
  workflow code, per the paper's programming model) run as real threads that
  block against virtual time.  Virtual time only advances when every driver
  thread is blocked, which makes workload benchmarks deterministic and lets a
  single CPU emulate minutes of cluster time in milliseconds.

* ``RealTimeKernel`` — wall-clock execution with ``threading.Timer``.  Used by
  the serving examples that drive actual JAX computation.

Both expose the same interface: ``now()``, ``schedule(delay, fn)``,
``sleep(dt)``, ``wait_event(evt, timeout)``, and driver thread registration.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from typing import Callable, Optional


class EventHandle:
    """Returned by ``SimKernel.schedule``; ``cancel()`` makes the event a
    no-op and releases its liveness contribution immediately."""

    __slots__ = ("fn", "periodic", "cancelled")

    def __init__(self, fn: Callable[[], None], periodic: bool) -> None:
        self.fn = fn
        self.periodic = periodic
        self.cancelled = False


class Kernel:
    """Interface shared by both kernels."""

    def now(self) -> float:
        raise NotImplementedError

    def schedule(self, delay: float, fn: Callable[[], None], *, tag: str = "",
                 periodic: bool = False) -> None:
        """``periodic=True`` marks housekeeping events (e.g. the global
        controller tick) that must not keep the simulation alive: the kernel
        quiesces when only periodic events remain and all drivers are blocked.
        """
        raise NotImplementedError

    def sleep(self, duration: float) -> None:
        raise NotImplementedError

    def wait_event(self, evt: threading.Event, timeout: Optional[float] = None) -> bool:
        raise NotImplementedError

    def spawn_driver(self, fn: Callable[[], None], name: str = "driver") -> threading.Thread:
        raise NotImplementedError

    def run(self) -> None:
        """Run until no events remain and all drivers have finished."""
        raise NotImplementedError


class SimKernel(Kernel):
    """Deterministic virtual-time kernel.

    Invariant: the simulator pops the next event only when ``_runnable == 0``,
    i.e. every registered driver thread is blocked in ``sleep``/``wait_event``
    (or has exited).  Events fire in (time, seq) order, so runs are
    reproducible regardless of OS thread scheduling.
    """

    def __init__(self) -> None:
        self._now = 0.0
        self._heap: list = []
        self._seq = itertools.count()
        self._lock = threading.RLock()   # re-entrant: wait_event schedules
        self._cv = threading.Condition(self._lock)
        self._runnable = 0          # driver threads currently executing
        self._drivers: list[threading.Thread] = []
        self._np_count = 0          # non-periodic events pending
        self._wake_queue: list = [] # deferred driver wakeups (determinism)
        self._stopping = False

    # ------------------------------------------------------------------ time
    def now(self) -> float:
        return self._now

    def schedule(self, delay: float, fn: Callable[[], None], *, tag: str = "",
                 periodic: bool = False) -> EventHandle:
        if delay < 0:
            delay = 0.0
        handle = EventHandle(fn, periodic)
        with self._lock:
            heapq.heappush(self._heap,
                           (self._now + delay, next(self._seq), handle, tag))
            if not periodic:
                self._np_count += 1
            self._cv.notify_all()
        return handle

    def cancel(self, handle: EventHandle) -> None:
        with self._lock:
            if not handle.cancelled:
                handle.cancelled = True
                if not handle.periodic:
                    self._np_count -= 1
                self._cv.notify_all()

    # --------------------------------------------------------------- drivers
    def spawn_driver(self, fn: Callable[[], None], name: str = "driver") -> threading.Thread:
        def body() -> None:
            try:
                fn()
            finally:
                with self._lock:
                    self._runnable -= 1
                    self._cv.notify_all()

        with self._lock:
            self._runnable += 1
        t = threading.Thread(target=body, name=name, daemon=True)
        self._drivers.append(t)
        t.start()
        return t

    def _block_driver(self) -> None:
        """Caller must hold the lock."""
        self._runnable -= 1
        self._cv.notify_all()

    def _unblock_driver_locked(self) -> None:
        self._runnable += 1

    def sleep(self, duration: float) -> None:
        evt = threading.Event()

        def wake() -> None:
            with self._lock:
                self._unblock_driver_locked()
            evt.set()

        self.schedule(duration, wake, tag="sleep-wake")
        with self._lock:
            self._block_driver()
        evt.wait()

    def wait_event(self, evt: threading.Event, timeout: Optional[float] = None) -> bool:
        """Block the driver thread until ``evt`` is set (in virtual time).

        The waker must call ``kernel.notify(evt)`` (below) rather than
        ``evt.set()`` directly so the runnable count stays consistent.
        """
        with self._lock:
            if evt.is_set():
                return True
            waiters = self._waiters_for(evt)
            me = threading.Event()
            deadline_fired = [False]
            timeout_handle: list = [None]
            waiters.append((me, timeout_handle))
            if timeout is not None:
                def timeout_fire() -> None:
                    with self._lock:
                        w = self._waiters_for(evt)
                        entry = next((x for x in w if x[0] is me), None)
                        if entry is None:
                            return
                        w.remove(entry)
                        deadline_fired[0] = True
                        self._unblock_driver_locked()
                    me.set()
                timeout_handle[0] = self.schedule(timeout, timeout_fire,
                                                  tag="wait-timeout")
            self._block_driver()
        me.wait()
        return not deadline_fired[0]

    def _waiters_for(self, evt: threading.Event) -> list:
        w = getattr(evt, "_sim_waiters", None)
        if w is None:
            w = []
            evt._sim_waiters = w  # type: ignore[attr-defined]
        return w

    def notify(self, evt: threading.Event) -> None:
        """Set ``evt`` and wake sim-blocked drivers waiting on it.

        Wakeups are DEFERRED to the simulator loop and delivered one driver
        at a time (the loop waits for each woken driver to block again
        before delivering the next).  This serialization makes runs
        deterministic: without it, simultaneously-woken driver threads race
        to schedule their next events and the event order depends on OS
        scheduling.  Safe to call from event handlers or driver threads.
        """
        with self._lock:
            evt.set()
            waiters = self._waiters_for(evt)
            pending = list(waiters)
            waiters.clear()
            for _me, th in pending:
                if th[0] is not None:
                    self.cancel(th[0])
            self._wake_queue.extend(me for me, _th in pending)
            self._cv.notify_all()

    # ------------------------------------------------------------------- run
    def run(self, max_time: float = float("inf"), max_events: int = 50_000_000) -> float:
        """Process events until quiescent.  Returns final virtual time."""
        events = 0
        while True:
            with self._lock:
                # Wait for all drivers to block (or exit).
                while self._runnable > 0:
                    self._cv.wait(timeout=30.0)
                if self._wake_queue:
                    # deliver exactly one deferred wakeup, then re-wait
                    me = self._wake_queue.pop(0)
                    self._unblock_driver_locked()
                    me.set()
                    continue
                if self._np_count == 0:
                    # Only periodic housekeeping (or nothing) remains and every
                    # driver is blocked/finished -> quiescent.  Drivers blocked
                    # forever at this point indicate a workload deadlock; we
                    # return either way (threads are daemonic).
                    return self._now
                t, _, handle, _tag = self._heap[0]
                if handle.cancelled:
                    heapq.heappop(self._heap)
                    continue  # np_count already released at cancel time
                if t > max_time:
                    # beyond the horizon: leave the event queued so a later
                    # ``run`` call (staged execution) still processes it
                    self._now = max_time
                    return self._now
                heapq.heappop(self._heap)
                if not handle.periodic:
                    self._np_count -= 1
                self._now = t
            handle.fn()  # may wake drivers; loop re-waits for runnable==0
            events += 1
            if events >= max_events:
                raise RuntimeError("SimKernel: max_events exceeded (runaway loop?)")


class RealTimeKernel(Kernel):
    """Wall-clock kernel for live serving."""

    def __init__(self) -> None:
        self._t0 = time.monotonic()
        self._drivers: list[threading.Thread] = []
        self._timers: list[threading.Timer] = []
        self._lock = threading.Lock()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def schedule(self, delay: float, fn: Callable[[], None], *, tag: str = "",
                 periodic: bool = False) -> None:
        timer = threading.Timer(max(0.0, delay), fn)
        timer.daemon = True
        with self._lock:
            self._timers = [t for t in self._timers if t.is_alive()]
            self._timers.append(timer)
        timer.start()

    def sleep(self, duration: float) -> None:
        time.sleep(duration)

    def wait_event(self, evt: threading.Event, timeout: Optional[float] = None) -> bool:
        return evt.wait(timeout)

    def notify(self, evt: threading.Event) -> None:
        evt.set()

    def spawn_driver(self, fn: Callable[[], None], name: str = "driver") -> threading.Thread:
        t = threading.Thread(target=fn, name=name, daemon=True)
        self._drivers.append(t)
        t.start()
        return t

    def run(self, max_time: float = float("inf"), max_events: int = 0) -> float:
        for t in self._drivers:
            t.join()
        return self.now()
