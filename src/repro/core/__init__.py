"""repro.core — NALAR's contribution: futures-centric agent serving runtime.

Public API:
    NalarRuntime, deployment          runtime + entry point
    AgentSpec, parse_spec, emulated   agent declaration / stub generation
    Directives                        runtime hints (Table 1)
    Future                            coordination handle (§3.2)
    ManagedList/ManagedDict           managed state (§3.3)
    Policy, ActionSink, ClusterView   policy interface (§4.2, Table 2)
    + the default/example policy library
"""

from .clock import Kernel, RealTimeKernel, SimKernel
from .controller_global import GlobalController
from .controller_local import ComponentController, LocalSchedule
from .directives import Directives
from .executor import (AgentInstance, EmulatedMethod, EngineBackedMethod,
                       FixedLatency, LatencyModel, LLMLatency,
                       LognormalLatency)
from .future import (DeadlineExceeded, Future, FutureCancelled,
                     FutureMetadata, FutureState, FutureTable, InstanceDied)
from .kv_registry import KVRegistry, Residency
from .node_store import NodeStore, StoreCluster
from .policy import (Action, ActionSink, ClusterView, HedgePolicy,
                     HighPrioritySessionPolicy,
                     HoLMitigationPolicy, InstanceView, KVAffinityPolicy,
                     LoadBalancePolicy, LPTPolicy, LPTSchedule, Policy,
                     PolicyChain, ResourceReassignmentPolicy, RetryPolicy,
                     SRTFPolicy, SRTFSchedule, TierRoutePolicy,
                     default_policies)
from .runtime import NalarRuntime, Router, current_runtime, deployment
from .session import SessionRegistry, get_context, set_context
from .state import (ManagedDict, ManagedList, SessionStateStore,
                    SessionTranscript, managedDict, managedList)
from .stubs import AgentSpec, Stub, emulated, parse_spec
from .telemetry import Telemetry

__all__ = [
    "AgentInstance", "AgentSpec", "Action", "ActionSink", "ClusterView",
    "ComponentController", "DeadlineExceeded", "Directives", "EmulatedMethod",
    "EngineBackedMethod", "FixedLatency",
    "Future", "FutureCancelled", "FutureMetadata", "FutureState",
    "FutureTable", "GlobalController", "HedgePolicy",
    "HighPrioritySessionPolicy",
    "HoLMitigationPolicy", "InstanceDied",
    "InstanceView", "KVAffinityPolicy", "Kernel", "KVRegistry",
    "LatencyModel", "LLMLatency",
    "LoadBalancePolicy", "LocalSchedule", "LognormalLatency", "LPTPolicy",
    "LPTSchedule", "ManagedDict", "ManagedList", "NalarRuntime", "NodeStore",
    "Policy", "PolicyChain", "RealTimeKernel", "Residency",
    "ResourceReassignmentPolicy", "RetryPolicy", "Router", "SRTFPolicy",
    "SRTFSchedule", "TierRoutePolicy",
    "SessionRegistry", "SessionStateStore", "SessionTranscript", "SimKernel",
    "StoreCluster",
    "Stub", "Telemetry", "current_runtime", "default_policies", "deployment",
    "emulated", "get_context", "managedDict", "managedList", "parse_spec",
    "set_context",
]
