"""Managed state layer (paper §3.3, §4.3.2).

``managedList`` / ``managedDict`` look like ordinary Python containers but are
runtime-tracked entities with user-session-based identities.  Logical state is
indexed by (session_id, agent_type, name) in the node store; the physical copy
lives wherever the owning agent instance runs and moves with session
migration.  To the developer the state appears local and stable.

Design notes mirroring the paper:
* the local controller always knows which session a request belongs to, so
  state access needs no explicit session plumbing (the session id comes from
  the thread-local execution context);
* when an agent begins serving a request, the controller consults the node
  store and reconstructs the managed containers ("materialization");
* migration transfers the serialized state between node stores and updates
  the placement index.
"""

from __future__ import annotations

import copy
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .session import get_context

# sentinel recorded in an epoch's undo log when the state object did not
# exist before the epoch's first touch (rollback deletes it again)
_MISSING = object()


class SessionStateStore:
    """Authoritative registry of managed state, layered on node stores.

    Keys: (session_id, agent_type, name) -> (node_id, payload).
    The payload is the *logical* value; placement (node_id) is runtime-owned.

    **State epochs (consistent retries).**  Every agent-method attempt runs
    inside an epoch keyed by ``(fid, attempt)``: the controller opens it via
    ``begin_epoch`` before invoking user code, and the first touch of each
    state object inside the epoch records a deep-copy undo snapshot.  On
    success the epoch is committed (snapshots dropped); on failure it is
    rolled back *before* the retry re-executes, so ``ManagedList`` /
    ``ManagedDict`` / ``SessionTranscript`` mutations are exactly-once
    across retries.  Snapshots store *logical* values — rollback writes
    through the current placement, so a migration landing between the failed
    attempt and the retry restores correctly at the new node.

    Epochs cover the failing method's own writes.  A retried *composite*
    re-issues its nested stub calls as fresh futures with fresh epochs;
    nested effects should be idempotent or live in the leaf that owns them.
    """

    def __init__(self, store_cluster) -> None:
        self._cluster = store_cluster
        self._lock = threading.RLock()
        # (sid, agent_type, name) -> node_id  (placement index)
        self._placement: Dict[Tuple[str, str, str], str] = {}
        # epoch token -> {(sid, agent_type, name): pre-epoch value | _MISSING}
        self._epochs: Dict[Any, Dict[Tuple[str, str, str], Any]] = {}
        # per-thread stack of active epoch tokens (innermost = writes owner)
        self._epoch_tl = threading.local()
        # rolled-back epochs (bounded, insertion-ordered).  A hard-killed or
        # cancelled *composite* attempt keeps executing on its driver thread
        # (threads cannot be killed); once its epoch is rolled back, any
        # further write it makes must be DROPPED — un-journaled writes from
        # a superseded attempt would break the exactly-once guarantee.
        self._aborted: Dict[Any, None] = {}

    # ------------------------------------------------------------- epochs
    def _epoch_stack(self) -> list:
        st = getattr(self._epoch_tl, "stack", None)
        if st is None:
            st = []
            self._epoch_tl.stack = st
        return st

    def begin_epoch(self, token: Any) -> None:
        """Open (or re-bind) the undo log for one execution attempt."""
        with self._lock:
            self._epochs.setdefault(token, {})
        self._epoch_stack().append(token)

    def end_epoch_binding(self) -> None:
        """Unbind the innermost epoch from this thread (the undo log itself
        survives until commit/rollback — the completion path owns that)."""
        st = self._epoch_stack()
        if st:
            st.pop()

    _MAX_ABORTED = 4096

    def _active_token(self) -> Any:
        st = self._epoch_stack()
        return st[-1] if st else None

    def _active_aborted(self) -> bool:
        """Is the calling thread executing a rolled-back attempt?
        (Caller holds ``_lock``.)"""
        t = self._active_token()
        return t is not None and t in self._aborted

    def commit_epoch(self, token: Any) -> None:
        """Attempt succeeded: its writes are final, drop the undo log."""
        with self._lock:
            self._epochs.pop(token, None)

    def rollback_epoch(self, token: Any) -> int:
        """Attempt failed: restore every state object it touched.

        Returns the number of restored objects.  Restores go through the
        *current* placement, which makes rollback correct even when the
        session migrated after the snapshot was taken.
        """
        with self._lock:
            snap = self._epochs.pop(token, None)
            # tombstone the attempt even when it wrote nothing yet: its
            # (possibly still-running) thread may write later
            self._aborted[token] = None
            while len(self._aborted) > self._MAX_ABORTED:
                self._aborted.pop(next(iter(self._aborted)))
            if not snap:
                return 0
            n = 0
            for (sid, at, name), prior in snap.items():
                node = self._placement.get((sid, at, name))
                key = self._key(sid, at, name)
                if prior is _MISSING:
                    if node is not None:
                        self._placement.pop((sid, at, name), None)
                        self._cluster.get(node).delete(key)
                elif node is not None:
                    self._cluster.get(node).hset(
                        key, "value", copy.deepcopy(prior))
                n += 1
            return n

    def _note(self, sid: str, agent_type: str, name: str, prior: Any) -> None:
        """Record the pre-epoch value on first touch (caller holds _lock)."""
        st = self._epoch_stack()
        if not st:
            return
        snap = self._epochs.get(st[-1])
        if snap is None:
            return
        key = (sid, agent_type, name)
        if key not in snap:
            snap[key] = prior if prior is _MISSING else copy.deepcopy(prior)

    @staticmethod
    def _key(sid: str, agent_type: str, name: str) -> str:
        return f"state:{sid}:{agent_type}:{name}"

    def load(self, sid: str, agent_type: str, name: str, node_id: str,
             default: Any) -> Any:
        with self._lock:
            aborted = self._active_aborted()
            placed = self._placement.get((sid, agent_type, name))
            if placed is None:
                if aborted:
                    # a superseded attempt must not create state objects
                    return default
                # first touch ever: inside an epoch, rollback must delete it
                self._note(sid, agent_type, name, _MISSING)
                self._placement[(sid, agent_type, name)] = node_id
                store = self._cluster.get(node_id)
                store.hset(self._key(sid, agent_type, name), "value", default)
                return default
            store = self._cluster.get(placed)
            v = store.hget(self._key(sid, agent_type, name), "value")
            if aborted:
                # read-only for zombies: no journaling, no placement moves —
                # and a COPY, because callers (ManagedList.append) mutate
                # the returned object in place before saving
                return copy.deepcopy(v) if v is not None else default
            # epoch undo log: snapshot the pristine value before the caller
            # mutates the returned object in place (ManagedList.append etc.)
            self._note(sid, agent_type, name, v if v is not None else default)
            if placed != node_id:
                # State lives elsewhere: materialize locally (the runtime moved
                # the request here, so the state follows — §4.3.2).
                self.migrate(sid, agent_type, name, node_id)
            return v if v is not None else default

    def save(self, sid: str, agent_type: str, name: str, value: Any) -> None:
        with self._lock:
            if self._active_aborted():
                return      # drop writes from superseded (rolled-back) attempts
            node_id = self._placement.get((sid, agent_type, name))
            if node_id is None:
                return
            key = self._key(sid, agent_type, name)
            if self._epoch_stack():
                # write-without-load (e.g. ManagedList.clear): capture the
                # pre-overwrite value if this epoch hasn't touched the key
                # yet.  Epoch-less writers (the engine bridge's pump thread)
                # skip the read-before-write entirely.
                cur = self._cluster.get(node_id).hget(key, "value")
                self._note(sid, agent_type, name,
                           cur if cur is not None else _MISSING)
            self._cluster.get(node_id).hset(key, "value", value)

    def migrate(self, sid: str, agent_type: str, name: str, dst_node: str) -> int:
        """Move one state object; returns payload size estimate (bytes-ish)."""
        with self._lock:
            src_node = self._placement.get((sid, agent_type, name))
            if src_node is None or src_node == dst_node:
                self._placement[(sid, agent_type, name)] = dst_node
                return 0
            key = self._key(sid, agent_type, name)
            src = self._cluster.get(src_node)
            val = src.hget(key, "value")
            src.delete(key)
            self._cluster.get(dst_node).hset(key, "value", val)
            self._placement[(sid, agent_type, name)] = dst_node
            return _sizeof(val)

    def migrate_session(self, sid: str, agent_type: str, dst_node: str) -> int:
        """Move all state of (session, agent) to dst.  Returns total bytes."""
        with self._lock:
            keys = [k for k in self._placement if k[0] == sid and k[1] == agent_type]
        return sum(self.migrate(sid, agent_type, name, dst_node)
                   for (_, _, name) in keys)

    def session_state_names(self, sid: str, agent_type: str) -> List[str]:
        with self._lock:
            return [n for (s, a, n) in self._placement
                    if s == sid and a == agent_type]

    def placement_of(self, sid: str, agent_type: str, name: str) -> Optional[str]:
        with self._lock:
            return self._placement.get((sid, agent_type, name))

    def drop_session(self, sid: str) -> None:
        with self._lock:
            keys = [k for k in self._placement if k[0] == sid]
            for k in keys:
                node = self._placement.pop(k)
                self._cluster.get(node).delete(self._key(*k))


def _sizeof(v: Any) -> int:
    try:
        import sys
        if isinstance(v, (list, tuple)):
            return sum(_sizeof(i) for i in v) + 56
        if isinstance(v, dict):
            return sum(_sizeof(k) + _sizeof(x) for k, x in v.items()) + 64
        return sys.getsizeof(v)
    except Exception:
        return 64


# --------------------------------------------------------------------------
# Developer-facing containers.  They bind lazily: the first access inside an
# agent resolves (session, agent_type, node) from the execution context that
# the component controller installed before invoking user code.
# --------------------------------------------------------------------------
class _ManagedBase:
    def __init__(self, name: str, runtime=None) -> None:
        self._name = name
        self._runtime = runtime  # bound at first access if None

    def _bind(self) -> Tuple[SessionStateStore, str, str, str]:
        from .runtime import current_runtime
        rt = self._runtime or current_runtime()
        if rt is None:
            raise RuntimeError(
                "managed state used outside a NALAR runtime; run the workflow "
                "via deployment.main() or nalar.testing.local_runtime()")
        sid, _rid, caller = get_context()
        agent_type = caller.split(":")[0]
        node = rt.node_of_instance(caller)
        rt.mark_uses_managed_state(agent_type)
        return rt.state_store, sid or "_global", agent_type, node


class ManagedList(_ManagedBase):
    """Drop-in list with session-scoped identity and runtime-managed placement."""

    def _get(self) -> list:
        store, sid, at, node = self._bind()
        return store.load(sid, at, self._name, node, default=[])

    def _put(self, v: list) -> None:
        store, sid, at, _ = self._bind()
        store.save(sid, at, self._name, v)

    def append(self, item: Any) -> None:
        v = self._get(); v.append(item); self._put(v)

    def extend(self, items) -> None:
        v = self._get(); v.extend(items); self._put(v)

    def __getitem__(self, i):
        return self._get()[i]

    def __setitem__(self, i, val) -> None:
        v = self._get(); v[i] = val; self._put(v)

    def __len__(self) -> int:
        return len(self._get())

    def __iter__(self) -> Iterator:
        return iter(self._get())

    def __contains__(self, item) -> bool:
        return item in self._get()

    def clear(self) -> None:
        self._put([])

    def snapshot(self) -> list:
        return list(self._get())


class ManagedDict(_ManagedBase):
    """Drop-in dict with session-scoped identity and runtime-managed placement."""

    def _get(self) -> dict:
        store, sid, at, node = self._bind()
        return store.load(sid, at, self._name, node, default={})

    def _put(self, v: dict) -> None:
        store, sid, at, _ = self._bind()
        store.save(sid, at, self._name, v)

    def __getitem__(self, k):
        v = self._get()
        if k not in v:
            raise KeyError(k)
        return v[k]

    def __setitem__(self, k, val) -> None:
        v = self._get(); v[k] = val; self._put(v)

    def __delitem__(self, k) -> None:
        v = self._get(); del v[k]; self._put(v)

    def get(self, k, default=None):
        return self._get().get(k, default)

    def setdefault(self, k, default=None):
        v = self._get()
        out = v.setdefault(k, default)
        self._put(v)
        return out

    def __len__(self) -> int:
        return len(self._get())

    def __iter__(self) -> Iterator:
        return iter(self._get())

    def __contains__(self, k) -> bool:
        return k in self._get()

    def items(self):
        return self._get().items()

    def keys(self):
        return self._get().keys()

    def values(self):
        return self._get().values()

    def clear(self) -> None:
        self._put({})

    def snapshot(self) -> dict:
        return dict(self._get())


class SessionTranscript:
    """Per-(session, agent_type) token transcript kept in managed state.

    The engine bridge uses this to make prefix-KV reuse *semantically* real:
    every LLM call in a session appends (prompt + generated) token ids here,
    so a follow-up call knows the full conversation context.  When the
    engine still holds the session's KV cache (per ``KVRegistry``), only the
    new suffix is sent; when the cache was evicted or the session migrated
    to a cold instance, the transcript rebuilds the full context in one
    prefill.  Because it lives in the ``SessionStateStore``, the transcript
    moves with session migration like any other managed state (§3.3).
    """

    NAME = "__llm_transcript__"

    def __init__(self, state_store: SessionStateStore, agent_type: str,
                 node_id: str) -> None:
        self._store = state_store
        self._agent_type = agent_type
        self._node = node_id

    def tokens(self, session_id: str) -> list:
        """The session's transcript, materialized at this binding's node.

        ``SessionStateStore.load`` moves the logical transcript here when it
        is placed elsewhere — which makes this call the state-layer half of
        cross-replica migration: the destination bridge's transcript binding
        reads the tokens (materializing them at the destination node) and
        ``serving.pool.EnginePool`` replays them into the destination
        engine's cache.  Token-level replay is what makes the move work
        across *heterogeneous* replicas, where raw KV pages would not be
        layout-compatible."""
        return list(self._store.load(session_id, self._agent_type, self.NAME,
                                     self._node, default=[]))

    def extend(self, session_id: str, new_tokens: list,
               max_tokens: Optional[int] = None) -> None:
        """Append tokens; with ``max_tokens``, keep only the trailing window
        (tokens beyond the engine's context budget can never be prefilled
        again, so storing them only bloats migration payloads)."""
        cur = self._store.load(session_id, self._agent_type, self.NAME,
                               self._node, default=[])
        out = list(cur) + [int(t) for t in new_tokens]
        if max_tokens is not None and len(out) > max_tokens:
            out = out[-max_tokens:]
        self._store.save(session_id, self._agent_type, self.NAME, out)

    def clear(self, session_id: str) -> None:
        self._store.save(session_id, self._agent_type, self.NAME, [])


# aliases matching the paper's naming
managedList = ManagedList
managedDict = ManagedDict
